//! The `digest serve` daemon: a bounded-concurrency TCP front end over
//! [`ModelRegistry`] + [`InferenceEngine`].
//!
//! Architecture (all `std::net`, zero new dependencies):
//!
//! * **One non-blocking accept loop** (the thread that calls
//!   [`Server::run`]).  Between accepts it polls the optional
//!   `--watch` file for hot model rollover and checks the shutdown
//!   flag, so the daemon needs no extra timer threads.
//! * **Thread-per-connection handlers, capped at `max_conns`.**  The
//!   accept loop increments the active-connection count *before*
//!   spawning, so the cap is exact: connection `max_conns + 1` gets a
//!   structured [`Response::Busy`] frame — explicit backpressure, never
//!   a hang or a silent drop.  Handler threads do blocking socket I/O
//!   only; **all compute dispatches through the shared
//!   [`InferenceEngine`]** onto the process-wide
//!   [`crate::tensor::pool::ChunkPool`], whose submission lock
//!   serializes chunk fan-outs — concurrent clients therefore get
//!   answers bit-identical to serial `predict` calls (asserted in
//!   `tests/integration_net.rs`).
//! * **Graceful drain on [`Request::Shutdown`]**: the flag flips, the
//!   accept loop stops accepting, every handler finishes the request it
//!   is serving (and closes keep-alive connections at the next 100 ms
//!   read-poll tick), and [`Server::run`] joins them all before
//!   returning the final counter snapshot.
//! * **Hot rollover**: when the watched file's (mtime, len) changes —
//!   the training-side [`crate::serve::ExportBestHook`] rewrites it via
//!   `util::write_atomic`, so a poll never sees a half-written file —
//!   the daemon re-reads it through [`ModelRegistry::reload`] (or first
//!   loads it, if the file did not exist at startup).
//!
//! Error policy per the wire docs: application failures are
//! [`Response::Error`] frames on a connection that stays usable;
//! framing-level corruption gets a best-effort `Error` frame and a
//! close, because the byte stream can no longer be trusted.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

use crate::config::ServeConfig;
use crate::serve::engine::{InferenceEngine, NodeQuery};
use crate::serve::model::InferenceModel;
use crate::serve::registry::ModelRegistry;
use crate::util::frame::{read_frame, write_frame, FrameRead};
use crate::util::lock_unpoisoned;
use crate::{eyre, Result};

use super::wire::{
    ModelInfo, Request, Response, WirePrediction, WireStats, MAX_FRAME, WIRE_VERSION,
};

/// How long a handler blocks in `read` before re-checking the shutdown
/// flag; bounds drain latency for idle keep-alive connections.
const READ_POLL: Duration = Duration::from_millis(100);

/// Accept-loop sleep when no connection is pending.
const ACCEPT_IDLE: Duration = Duration::from_millis(5);

/// A model plus the file it came from (if any) — file-backed models are
/// eligible for `Reload` and watch-driven rollover.
pub struct LoadedModel {
    pub model: InferenceModel,
    pub source: Option<String>,
}

/// Monotonic daemon counters, shared across handler threads.
#[derive(Default)]
struct Counters {
    accepted: AtomicU64,
    served: AtomicU64,
    busy_rejected: AtomicU64,
    app_errors: AtomicU64,
    frame_errors: AtomicU64,
    reloads: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

/// State shared between the accept loop and handler threads.
struct Shared {
    engine: Arc<InferenceEngine>,
    /// Registry plus name→source-path map under ONE mutex: handlers
    /// only hold it long enough to clone a model `Arc` (predict runs
    /// lock-free); `Reload` holds it across the file re-read so a
    /// concurrent predict never observes a half-swapped registry.
    models: Mutex<Models>,
    shutdown: AtomicBool,
    active: AtomicUsize,
    max_conns: usize,
    counters: Counters,
}

struct Models {
    registry: ModelRegistry,
    /// model name → path it was loaded from (Reload / rollover targets).
    sources: BTreeMap<String, String>,
}

impl Shared {
    fn stats(&self) -> WireStats {
        let models = lock_unpoisoned(&self.models).registry.len() as u32;
        WireStats {
            models,
            active_conns: self.active.load(Ordering::SeqCst) as u32,
            max_conns: self.max_conns as u32,
            accepted: self.counters.accepted.load(Ordering::Relaxed),
            served: self.counters.served.load(Ordering::Relaxed),
            busy_rejected: self.counters.busy_rejected.load(Ordering::Relaxed),
            app_errors: self.counters.app_errors.load(Ordering::Relaxed),
            frame_errors: self.counters.frame_errors.load(Ordering::Relaxed),
            reloads: self.counters.reloads.load(Ordering::Relaxed),
            bytes_in: self.counters.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.counters.bytes_out.load(Ordering::Relaxed),
            engine: self.engine.stats(),
        }
    }
}

/// Watch-file change detection state: last observed (mtime, len).
struct Watch {
    path: String,
    last: Option<(Option<SystemTime>, u64)>,
}

impl Watch {
    fn stat(path: &str) -> Option<(Option<SystemTime>, u64)> {
        let md = std::fs::metadata(path).ok()?;
        Some((md.modified().ok(), md.len()))
    }
}

/// The daemon; see module docs.  `bind` then `run` (blocking).
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    poll_every: Duration,
    watch: Option<Watch>,
}

impl Server {
    /// Validate the config, register (and fingerprint-validate) the
    /// models, initialise watch state, and bind the listener.  Fails
    /// fast on a model/graph mismatch rather than erroring per-request.
    pub fn bind(
        cfg: &ServeConfig,
        engine: Arc<InferenceEngine>,
        models: Vec<LoadedModel>,
    ) -> Result<Server> {
        cfg.validate()?;
        if models.is_empty() {
            return Err(eyre!("serve: no models to serve"));
        }
        let mut registry = ModelRegistry::new();
        let mut sources = BTreeMap::new();
        for lm in models {
            engine.validate_model(&lm.model)?;
            let name = lm.model.name().to_string();
            if let Some(path) = lm.source {
                sources.insert(name.clone(), path);
            }
            if registry.get(&name).is_ok() {
                return Err(eyre!("serve: duplicate model name {name:?}"));
            }
            registry.insert(lm.model);
        }
        let mut models = Models { registry, sources };
        let watch = match &cfg.watch {
            None => None,
            Some(path) => {
                let last = Watch::stat(path);
                if last.is_some() && !models.sources.values().any(|p| p == path) {
                    // watch target exists but wasn't among the CLI
                    // models: serve it from the start.
                    let arc = models.registry.load_file(path)?;
                    engine.validate_model(&arc)?;
                    models.sources.insert(arc.name().to_string(), path.clone());
                }
                Some(Watch {
                    path: path.clone(),
                    last,
                })
            }
        };
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| eyre!("serve: binding {:?}: {e}", cfg.addr))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| eyre!("serve: set_nonblocking: {e}"))?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                engine,
                models: Mutex::new(models),
                shutdown: AtomicBool::new(false),
                active: AtomicUsize::new(0),
                max_conns: cfg.max_conns,
                counters: Counters::default(),
            }),
            poll_every: Duration::from_millis(cfg.poll_ms),
            watch,
        })
    }

    /// The bound address — with `--addr 127.0.0.1:0` this is where the
    /// OS actually put us (ephemeral-port tests).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener
            .local_addr()
            .map_err(|e| eyre!("serve: local_addr: {e}"))
    }

    /// Serve until a `Shutdown` request: accept → handler threads,
    /// watch polling in the idle gaps, then a full drain (every handler
    /// joined) before returning the final counters.
    pub fn run(mut self) -> Result<WireStats> {
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut last_poll = Instant::now();
        while !self.shared.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let id = self.shared.counters.accepted.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_nodelay(true);
                    if self.shared.active.load(Ordering::SeqCst) >= self.shared.max_conns {
                        self.shared
                            .counters
                            .busy_rejected
                            .fetch_add(1, Ordering::Relaxed);
                        reject_busy(stream, &self.shared);
                        continue;
                    }
                    self.shared.active.fetch_add(1, Ordering::SeqCst);
                    let shared = self.shared.clone();
                    let spawned = std::thread::Builder::new()
                        .name(format!("digest-serve-{id}"))
                        .spawn(move || handle_conn(stream, shared));
                    match spawned {
                        Ok(h) => handles.push(h),
                        Err(e) => {
                            // undo the reservation; the client sees a close
                            self.shared.active.fetch_sub(1, Ordering::SeqCst);
                            eprintln!("[serve] spawning handler: {e}");
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if self.watch.is_some() && last_poll.elapsed() >= self.poll_every {
                        self.poll_watch();
                        last_poll = Instant::now();
                    }
                    std::thread::sleep(ACCEPT_IDLE);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    // transient accept failure (e.g. EMFILE): log, back
                    // off, keep serving existing connections
                    eprintln!("[serve] accept: {e}");
                    std::thread::sleep(ACCEPT_IDLE);
                }
            }
            handles.retain(|h| !h.is_finished());
        }
        // Drain: stop accepting (listener drops with self at return),
        // let every in-flight handler finish its current request.
        for h in handles {
            let _ = h.join();
        }
        Ok(self.shared.stats())
    }

    /// Watch-file poll: on (mtime, len) change, reload the model that
    /// was loaded from that path — or load the file fresh if it has
    /// just appeared.  Failures warn and keep the old model serving.
    fn poll_watch(&mut self) {
        let Some(watch) = self.watch.as_mut() else {
            return;
        };
        let cur = Watch::stat(&watch.path);
        if cur.is_none() || cur == watch.last {
            return;
        }
        // remember what we saw even if the load fails, so a bad file
        // warns once instead of once per poll tick
        watch.last = cur;
        match reload_path(&self.shared, &watch.path) {
            Ok(name) => {
                self.shared.counters.reloads.fetch_add(1, Ordering::Relaxed);
                println!("[serve] rollover: reloaded {name:?} from {}", watch.path);
            }
            Err(e) => eprintln!("[serve] rollover failed for {}: {e}", watch.path),
        }
    }
}

/// Reload the model loaded from `path` (registering it first if the
/// file is new), re-keying the source map if the artifact was renamed.
/// Returns the (possibly new) model name.
fn reload_path(shared: &Shared, path: &str) -> Result<String> {
    let mut models = lock_unpoisoned(&shared.models);
    let known = models
        .sources
        .iter()
        .find(|(_, p)| p.as_str() == path)
        .map(|(name, _)| name.clone());
    let arc = match &known {
        Some(name) => models.registry.reload(name, path)?,
        None => models.registry.load_file(path)?,
    };
    shared.engine.validate_model(&arc)?;
    let new_name = arc.name().to_string();
    if known.as_deref() != Some(new_name.as_str()) {
        if let Some(old) = known {
            models.sources.remove(&old);
        }
    }
    models.sources.insert(new_name.clone(), path.to_string());
    Ok(new_name)
}

/// Best-effort `Busy` frame to a connection over the cap, then close.
fn reject_busy(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_write_timeout(Some(READ_POLL));
    let busy = Response::Busy {
        active: shared.active.load(Ordering::SeqCst) as u32,
        max: shared.max_conns as u32,
    };
    if let Ok((op, payload)) = busy.encode() {
        if let Ok(n) = write_frame(&mut stream, op, &payload) {
            shared.counters.bytes_out.fetch_add(n, Ordering::Relaxed);
        }
    }
}

/// Decrements the active-connection count when the handler exits —
/// including by panic, so a crashed handler can never leak a
/// connection slot and wedge the daemon at `Busy`.
struct ActiveGuard(Arc<Shared>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One connection's request→response loop.
fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>) {
    let _guard = ActiveGuard(shared.clone());
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let mut hello_done = false;
    loop {
        let (opcode, payload) = match read_frame(&mut stream, MAX_FRAME) {
            Ok(FrameRead::Frame(op, p)) => (op, p),
            Ok(FrameRead::Closed) => return,
            Ok(FrameRead::TimedOut) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return; // drain: close idle keep-alive connections
                }
                continue;
            }
            Err(e) => {
                // framing broke: answer (best effort), then close —
                // the stream is no longer at a trustable boundary
                shared.counters.frame_errors.fetch_add(1, Ordering::Relaxed);
                send(
                    &mut stream,
                    &shared,
                    &Response::Error {
                        message: format!("framing error: {e}"),
                    },
                );
                return;
            }
        };
        shared
            .counters
            .bytes_in
            .fetch_add(5 + payload.len() as u64, Ordering::Relaxed);

        let request = match Request::decode(opcode, &payload) {
            Ok(req) => req,
            Err(e) => {
                // the frame boundary is intact — reply and keep serving
                shared.counters.app_errors.fetch_add(1, Ordering::Relaxed);
                if !send(
                    &mut stream,
                    &shared,
                    &Response::Error {
                        message: format!("bad request: {e}"),
                    },
                ) {
                    return;
                }
                continue;
            }
        };

        // handshake gate: the first frame must be a version-matched
        // Hello; anything else means the peer speaks another protocol
        // (or version), so its payload layouts cannot be trusted
        if !hello_done {
            match &request {
                Request::Hello { version } if version == WIRE_VERSION => {}
                Request::Hello { version } => {
                    shared.counters.app_errors.fetch_add(1, Ordering::Relaxed);
                    send(
                        &mut stream,
                        &shared,
                        &Response::Error {
                            message: format!(
                                "version mismatch: client {version:?}, server {WIRE_VERSION:?}"
                            ),
                        },
                    );
                    return;
                }
                _ => {
                    shared.counters.app_errors.fetch_add(1, Ordering::Relaxed);
                    send(
                        &mut stream,
                        &shared,
                        &Response::Error {
                            message: format!("expected {WIRE_VERSION:?} Hello handshake first"),
                        },
                    );
                    return;
                }
            }
            hello_done = true;
        }

        let shutting_down = matches!(request, Request::Shutdown);
        let response = dispatch(&shared, request);
        if matches!(response, Response::Error { .. }) {
            shared.counters.app_errors.fetch_add(1, Ordering::Relaxed);
        }
        if !send(&mut stream, &shared, &response) {
            return;
        }
        if shutting_down {
            shared.shutdown.store(true, Ordering::SeqCst);
            return;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return; // drain: this request was in flight, it completed
        }
    }
}

/// Encode + write one response, tracking bytes; false = connection gone.
fn send(stream: &mut TcpStream, shared: &Shared, resp: &Response) -> bool {
    let (op, payload) = match resp.encode() {
        Ok(x) => x,
        Err(e) => {
            // encoding failure (e.g. >u32 shape): degrade to an Error
            // frame rather than dropping the connection
            match (Response::Error {
                message: format!("encoding response: {e}"),
            })
            .encode()
            {
                Ok(x) => x,
                Err(_) => return false,
            }
        }
    };
    match write_frame(stream, op, &payload) {
        Ok(n) => {
            shared.counters.bytes_out.fetch_add(n, Ordering::Relaxed);
            true
        }
        Err(_) => false,
    }
}

/// Map one decoded request to its response.  Never panics; every
/// failure is a structured [`Response::Error`].
fn dispatch(shared: &Shared, request: Request) -> Response {
    match request {
        Request::Hello { .. } => Response::HelloOk {
            version: WIRE_VERSION.to_string(),
        },
        Request::Predict {
            model,
            nodes,
            top_k,
        } => {
            let arc = {
                let models = lock_unpoisoned(&shared.models);
                models.registry.get(&model)
            };
            let arc = match arc {
                Ok(a) => a,
                Err(e) => {
                    return Response::Error {
                        message: e.to_string(),
                    }
                }
            };
            let query = match nodes {
                None => NodeQuery::full(),
                Some(ids) => NodeQuery::nodes(ids.into_iter().map(|n| n as usize).collect()),
            }
            .with_top_k(top_k as usize);
            // compute runs on the shared ChunkPool via the engine; the
            // registry lock is already released
            match shared
                .engine
                .predict(&arc, &query)
                .and_then(|p| WirePrediction::from_prediction(&p))
            {
                Ok(wp) => {
                    shared.counters.served.fetch_add(1, Ordering::Relaxed);
                    Response::Prediction(wp)
                }
                Err(e) => Response::Error {
                    message: e.to_string(),
                },
            }
        }
        Request::ListModels => {
            let models = lock_unpoisoned(&shared.models);
            let infos: Result<Vec<ModelInfo>> = models
                .registry
                .list()
                .into_iter()
                .map(ModelInfo::from_model)
                .collect();
            match infos {
                Ok(list) => Response::ModelList(list),
                Err(e) => Response::Error {
                    message: e.to_string(),
                },
            }
        }
        Request::Reload { name } => {
            let targets: Vec<String> = {
                let models = lock_unpoisoned(&shared.models);
                if name.is_empty() {
                    models.sources.values().cloned().collect()
                } else {
                    match models.sources.get(&name) {
                        Some(path) => vec![path.clone()],
                        None => {
                            return Response::Error {
                                message: format!(
                                    "model {name:?} was not loaded from a file (cannot reload)"
                                ),
                            }
                        }
                    }
                }
            };
            if targets.is_empty() {
                return Response::Error {
                    message: "no file-backed models to reload".to_string(),
                };
            }
            let mut reloaded = Vec::with_capacity(targets.len());
            for path in targets {
                match reload_path(shared, &path) {
                    Ok(name) => reloaded.push(name),
                    Err(e) => {
                        return Response::Error {
                            message: format!("reloading {path:?}: {e}"),
                        }
                    }
                }
            }
            shared
                .counters
                .reloads
                .fetch_add(reloaded.len() as u64, Ordering::Relaxed);
            Response::ReloadOk { reloaded }
        }
        Request::Stats => Response::Stats(shared.stats()),
        Request::Shutdown => Response::ShutdownOk,
    }
}
