//! Blocking `digest-wire-v1` client: the API under `digest query` and
//! the `digest bench-serve --remote` load generator.
//!
//! A [`Client`] owns one connection (handshake performed in
//! [`Client::connect`]) and issues sequential request→response calls.
//! Server-side [`Response::Error`] and [`Response::Busy`] frames
//! surface as structured `Err`s — [`is_busy`] distinguishes
//! backpressure from real failures so callers can retry.  Every client
//! tracks its own bytes on the wire ([`Client::bytes_out`] /
//! [`Client::bytes_in`]), which is how the load report measures
//! per-request wire cost.
//!
//! [`run_load`] drives N concurrent client threads for the latency
//! histogram bench.  Those threads are plain `std::thread` —
//! intentionally *outside* the ChunkPool (D003 pragma below): they are
//! I/O-bound request generators that must overlap in real time to
//! exercise the server's concurrency; all compute they trigger runs
//! server-side on the pool.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::serve::engine::{NodeQuery, Prediction};
use crate::util::frame::{read_frame, write_frame, FrameRead};
use crate::util::hist::LatencyHistogram;
use crate::{eyre, Result};

use super::wire::{
    predict_request, ModelInfo, Request, Response, WireStats, MAX_FRAME, WIRE_VERSION,
};

/// Marker embedded in the `Err` a [`Response::Busy`] frame becomes;
/// [`is_busy`] keys off it.
const BUSY_TAG: &str = "server busy";

/// True if this error is the server's `Busy` backpressure signal
/// (retryable) rather than a real failure.
pub fn is_busy(err: &anyhow::Error) -> bool {
    err.to_string().contains(BUSY_TAG)
}

/// One blocking connection to a `digest serve` daemon.
pub struct Client {
    stream: TcpStream,
    bytes_out: u64,
    bytes_in: u64,
}

impl Client {
    /// Connect and run the version handshake.  A server at its
    /// connection cap answers the connect with `Busy` — that surfaces
    /// here as an `Err` for which [`is_busy`] returns true.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| eyre!("connecting to {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
        let mut client = Client {
            stream,
            bytes_out: 0,
            bytes_in: 0,
        };
        match client.roundtrip(&Request::Hello {
            version: WIRE_VERSION.to_string(),
        })? {
            Response::HelloOk { version } if version == WIRE_VERSION => Ok(client),
            Response::HelloOk { version } => Err(eyre!(
                "version mismatch: server {version:?}, client {WIRE_VERSION:?}"
            )),
            other => Err(unexpected("HelloOk", &other)),
        }
    }

    /// Bytes this client has written to the socket (frames included).
    pub fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    /// Bytes this client has read from the socket.
    pub fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    /// Remote predict; the returned [`Prediction`] is bit-identical to
    /// what `InferenceEngine::predict` returns in-process.
    pub fn predict(&mut self, model: &str, query: &NodeQuery) -> Result<Prediction> {
        let req = predict_request(model, query)?;
        match self.roundtrip(&req)? {
            Response::Prediction(wp) => wp.into_prediction(),
            other => Err(unexpected("Prediction", &other)),
        }
    }

    /// List the models the daemon currently serves.
    pub fn list_models(&mut self) -> Result<Vec<ModelInfo>> {
        match self.roundtrip(&Request::ListModels)? {
            Response::ModelList(list) => Ok(list),
            other => Err(unexpected("ModelList", &other)),
        }
    }

    /// Ask the daemon to re-read model files: `""` = every file-backed
    /// model, otherwise one model by name.  Returns the (possibly
    /// re-keyed) names reloaded.
    pub fn reload(&mut self, name: &str) -> Result<Vec<String>> {
        match self.roundtrip(&Request::Reload {
            name: name.to_string(),
        })? {
            Response::ReloadOk { reloaded } => Ok(reloaded),
            other => Err(unexpected("ReloadOk", &other)),
        }
    }

    /// Engine + daemon counters.
    pub fn stats(&mut self) -> Result<WireStats> {
        match self.roundtrip(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected("Stats", &other)),
        }
    }

    /// Graceful daemon shutdown: in-flight requests complete, the
    /// listener closes, `digest serve` exits.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.roundtrip(&Request::Shutdown)? {
            Response::ShutdownOk => Ok(()),
            other => Err(unexpected("ShutdownOk", &other)),
        }
    }

    /// One request→response exchange, with byte accounting.
    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let (op, payload) = req.encode()?;
        self.bytes_out += write_frame(&mut self.stream, op, &payload)?;
        match read_frame(&mut self.stream, MAX_FRAME)? {
            FrameRead::Frame(op, payload) => {
                self.bytes_in += 5 + payload.len() as u64;
                Response::decode(op, &payload)
            }
            FrameRead::Closed => Err(eyre!("server closed the connection")),
            FrameRead::TimedOut => Err(eyre!("timed out waiting for the server's reply")),
        }
    }
}

/// Map the two out-of-band responses to structured errors; anything
/// else unexpected is a protocol bug.
fn unexpected(wanted: &str, got: &Response) -> anyhow::Error {
    match got {
        Response::Error { message } => eyre!("server error: {message}"),
        Response::Busy { active, max } => eyre!("{BUSY_TAG}: {active}/{max} connections"),
        other => eyre!("protocol error: expected {wanted}, got {other:?}"),
    }
}

/// What [`run_load`] measured: merged latency histogram + wire cost.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Requests that returned a prediction.
    pub completed: u64,
    /// Requests that errored (the first error message is kept).
    pub errors: u64,
    pub first_error: Option<String>,
    /// Wall-clock for the whole run (all clients, connect to join).
    pub elapsed_secs: f64,
    pub hist: LatencyHistogram,
    /// Total bytes written/read across all clients (handshakes included).
    pub bytes_out: u64,
    pub bytes_in: u64,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_secs > 0.0 {
            self.completed as f64 / self.elapsed_secs
        } else {
            0.0
        }
    }

    pub fn bytes_out_per_req(&self) -> f64 {
        per_req(self.bytes_out, self.completed)
    }

    pub fn bytes_in_per_req(&self) -> f64 {
        per_req(self.bytes_in, self.completed)
    }
}

fn per_req(bytes: u64, reqs: u64) -> f64 {
    if reqs > 0 {
        bytes as f64 / reqs as f64
    } else {
        0.0
    }
}

/// Drive `clients` concurrent connections, each issuing `requests`
/// sequential predicts, and merge the per-thread latency histograms.
/// A client that cannot connect fails the whole run (a load bench
/// against a saturated server is a configuration error — lower
/// `clients` below the daemon's `--max-conns`).
pub fn run_load(
    addr: &str,
    model: &str,
    query: &NodeQuery,
    clients: usize,
    requests: usize,
) -> Result<LoadReport> {
    if clients == 0 || requests == 0 {
        return Err(eyre!("load run needs clients >= 1 and requests >= 1"));
    }
    struct ThreadOut {
        hist: LatencyHistogram,
        completed: u64,
        errors: u64,
        first_error: Option<String>,
        bytes_out: u64,
        bytes_in: u64,
    }
    let t0 = Instant::now();
    // lint:allow(D003, load-generator threads are I/O-bound request drivers that must overlap in real time to exercise server concurrency; the compute they trigger runs server-side on the ChunkPool)
    let outs: Vec<Result<ThreadOut>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                s.spawn(move || -> Result<ThreadOut> {
                    let mut client = Client::connect(addr)?;
                    let mut out = ThreadOut {
                        hist: LatencyHistogram::new(),
                        completed: 0,
                        errors: 0,
                        first_error: None,
                        bytes_out: 0,
                        bytes_in: 0,
                    };
                    for _ in 0..requests {
                        let t = Instant::now();
                        match client.predict(model, query) {
                            Ok(_) => {
                                out.hist.record(t.elapsed().as_secs_f64());
                                out.completed += 1;
                            }
                            Err(e) => {
                                out.errors += 1;
                                out.first_error.get_or_insert_with(|| e.to_string());
                            }
                        }
                    }
                    out.bytes_out = client.bytes_out();
                    out.bytes_in = client.bytes_in();
                    Ok(out)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(eyre!("load-generator thread panicked")),
            })
            .collect()
    });
    let elapsed_secs = t0.elapsed().as_secs_f64();
    let mut report = LoadReport {
        clients,
        requests_per_client: requests,
        completed: 0,
        errors: 0,
        first_error: None,
        elapsed_secs,
        hist: LatencyHistogram::new(),
        bytes_out: 0,
        bytes_in: 0,
    };
    for out in outs {
        let out = out?;
        report.completed += out.completed;
        report.errors += out.errors;
        if report.first_error.is_none() {
            report.first_error = out.first_error;
        }
        report.hist.merge(&out.hist);
        report.bytes_out += out.bytes_out;
        report.bytes_in += out.bytes_in;
    }
    Ok(report)
}
