//! `serve::net` — the serving stack's network layer: the `digest
//! serve` daemon, its `digest-wire-v1` binary protocol, and the
//! blocking client under `digest query` / `digest bench-serve
//! --remote`.
//!
//! Three modules, `std::net` only (zero new dependencies):
//!
//! * [`wire`] — the versioned length-prefixed message codec
//!   ([`Request`] / [`Response`], byte-exact round trips, per-frame
//!   size caps, structured `Error` / `Busy` frames).  Transport
//!   framing lives in [`crate::util::frame`].
//! * [`server`] — the daemon: non-blocking accept loop +
//!   thread-per-connection handlers capped at `max_conns` (exact
//!   [`Response::Busy`] backpressure), compute dispatched through the
//!   shared [`crate::serve::InferenceEngine`] onto the process
//!   ChunkPool (concurrent clients ≡ serial predict, bit-exact),
//!   graceful [`Request::Shutdown`] drain, and hot model rollover by
//!   polling the training side's `export_best=` file.
//! * [`client`] — blocking [`Client`] (predict + admin verbs, per-call
//!   byte accounting) and the [`run_load`] concurrent load generator
//!   behind the latency-histogram bench.
//!
//! The codec and framing layer deliberately know nothing about
//! serving: they are the seed for the ROADMAP multi-process training
//! transport, which needs the same length-prefixed frames for
//! parameter/representation traffic.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{is_busy, run_load, Client, LoadReport};
pub use server::{LoadedModel, Server};
pub use wire::{ModelInfo, Request, Response, WirePrediction, WireStats, WIRE_VERSION};
