//! Virtual-time cost model for the simulated cluster.
//!
//! The paper's speedup/scalability numbers come from an 8×T4 GPU box.
//! Here, M workers are threads on one CPU, so wall-clock time cannot
//! reproduce Figures 4/5/7.  Instead every run advances a *virtual
//! clock*: real PJRT executions provide the numerics while this model
//! provides the timeline —
//!
//!   compute time  = step FLOPs / (device_flops · speed_factor_m)
//!   comm time     = latency + bytes / bandwidth
//!   epoch (sync)  = max_m(worker time) + aggregation
//!   overlap       = pull/push hidden behind layer compute (Fig. 2)
//!
//! Straggler injection (Fig. 7) adds a per-epoch random delay to chosen
//! workers, mirroring the paper's "8-10 s random delay" protocol.

use crate::util::Rng;

/// Cluster/device parameters.
///
/// Scaled from the paper's testbed (8×T4, PCIe, Plasma) to this repo's
/// CI-scale graphs: our per-subgraph FLOPs are ~10³ smaller than the
/// paper's, so the comm parameters are scaled by the same factor to
/// preserve the communication-to-compute *ratio* that drives every
/// timing figure (who wins, crossovers).  DESIGN.md §2 documents the
/// substitution; absolute virtual seconds are not comparable to the
/// paper's wall-clock, ratios are.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Per-device dense throughput (FLOP/s). T4 fp32 ≈ 8.1 TFLOPs.
    pub device_flops: f64,
    /// Per-op KVS/PS latency (s).
    pub net_latency: f64,
    /// Representation (KVS) bandwidth (bytes/s), scale-matched: rep
    /// traffic grows with graph size, which we shrank ~10^3.
    pub net_bandwidth: f64,
    /// Parameter (PS) bandwidth (bytes/s): model size does NOT scale
    /// with the graph, so parameters keep the testbed's PCIe rate.
    pub param_bandwidth: f64,
    /// Relative speed per worker (1.0 = nominal). Heterogeneity knob.
    pub speed_factors: Vec<f64>,
    /// Straggler injection: (worker id, min delay s, max delay s).
    pub straggler: Option<(usize, f64, f64)>,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            device_flops: 8.1e12,
            net_latency: 50e-6,
            net_bandwidth: 200e6,
            param_bandwidth: 12e9,
            speed_factors: Vec::new(),
            straggler: None,
        }
    }
}

impl CostModel {
    pub fn speed(&self, worker: usize) -> f64 {
        self.speed_factors.get(worker).copied().unwrap_or(1.0)
    }

    /// Seconds to execute `flops` on `worker`.
    pub fn compute_time(&self, worker: usize, flops: u64) -> f64 {
        flops as f64 / (self.device_flops * self.speed(worker))
    }

    /// Seconds to move `bytes` of *representations* through the KVS.
    pub fn comm_time(&self, bytes: u64) -> f64 {
        self.net_latency + bytes as f64 / self.net_bandwidth
    }

    /// Seconds to move `bytes` of *parameters/gradients* through the PS.
    pub fn param_time(&self, bytes: u64) -> f64 {
        self.net_latency + bytes as f64 / self.param_bandwidth
    }

    /// Straggler delay drawn for this worker/epoch (0 if not straggler).
    pub fn straggler_delay(&self, worker: usize, rng: &mut Rng) -> f64 {
        match self.straggler {
            Some((w, lo, hi)) if w == worker => lo + rng.f64() * (hi - lo),
            _ => 0.0,
        }
    }

    /// Per-epoch worker time combining compute and I/O.
    ///
    /// `layer_compute[l]` are per-layer compute seconds, `layer_io[l]`
    /// the pull/push seconds adjacent to layer l.  With overlap on
    /// (Fig. 2) the I/O hides behind the *previous* layer's compute:
    /// t = Σ max(compute_l, io_l); off: t = Σ (compute_l + io_l).
    pub fn worker_epoch_time(
        &self,
        layer_compute: &[f64],
        layer_io: &[f64],
        overlap: bool,
        straggle: f64,
    ) -> f64 {
        assert_eq!(layer_compute.len(), layer_io.len());
        let t: f64 = if overlap {
            layer_compute
                .iter()
                .zip(layer_io)
                .map(|(c, i)| c.max(*i))
                .sum()
        } else {
            layer_compute.iter().sum::<f64>() + layer_io.iter().sum::<f64>()
        };
        t + straggle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_scales_with_speed() {
        let mut cm = CostModel::default();
        cm.speed_factors = vec![1.0, 0.5];
        let t0 = cm.compute_time(0, 1_000_000_000);
        let t1 = cm.compute_time(1, 1_000_000_000);
        assert!((t1 / t0 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn comm_time_has_latency_floor() {
        let cm = CostModel::default();
        assert!(cm.comm_time(0) >= cm.net_latency);
        let big = cm.comm_time(200_000_000);
        assert!((big - (cm.net_latency + 1.0)).abs() < 1e-6);
    }

    #[test]
    fn overlap_hides_io() {
        let cm = CostModel::default();
        let comp = [1.0, 1.0, 1.0];
        let io = [0.5, 0.5, 0.5];
        let with = cm.worker_epoch_time(&comp, &io, true, 0.0);
        let without = cm.worker_epoch_time(&comp, &io, false, 0.0);
        assert!((with - 3.0).abs() < 1e-12);
        assert!((without - 4.5).abs() < 1e-12);
    }

    #[test]
    fn io_bound_layers_dominate_under_overlap() {
        let cm = CostModel::default();
        let t = cm.worker_epoch_time(&[0.1, 0.1], &[1.0, 1.0], true, 0.0);
        assert!((t - 2.0).abs() < 1e-12);
    }

    #[test]
    fn straggler_delay_in_range_and_only_for_target() {
        let mut cm = CostModel::default();
        cm.straggler = Some((2, 8.0, 10.0));
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let d = cm.straggler_delay(2, &mut rng);
            assert!((8.0..=10.0).contains(&d));
            assert_eq!(cm.straggler_delay(1, &mut rng), 0.0);
        }
    }
}
