//! `digest` — CLI for the DIGEST distributed GNN training framework.
//!
//! ```text
//! digest list                               # datasets + artifacts
//! digest generate --dataset arxiv-s         # dataset stats
//! digest partition --dataset arxiv-s --parts 4 --algo metis
//! digest train [--config run.json] [key=value ...] [--csv out.csv]
//! digest train --distributed parts=2             # process-per-partition run
//! digest ps-serve --addr 127.0.0.1:7878 parts=2  # training-plane daemon
//! digest worker --part 0 --connect 127.0.0.1:7878
//! digest experiment <id|all> [--out-dir results] [--quick] [--seed N]
//! digest serve model.json --watch best.json      # TCP inference daemon
//! digest query --nodes 0,1,2 --topk 3            # remote predict over digest-wire-v1
//! digest bench-serve --remote --clients 4        # latency-histogram load bench
//! ```
//!
//! Training knobs are `key=value` overrides on `config::RunConfig`
//! (dataset, model, parts, method, epochs, sync_interval, lr, optimizer,
//! overlap, eval_every, threads, seed, ...).  `threads=0` (default)
//! auto-sizes the worker pool to min(parts, cores); any thread count
//! produces bit-identical results.
//!
//! Session knobs (`coordinator::session` / `coordinator::hooks`):
//! `save_to=ck.json save_every=K` checkpoints the *full* training state
//! every K epochs (and at the end), `load_from=ck.json` resumes it
//! bit-exactly (raise `epochs` above the checkpoint's count to
//! continue), `stream_csv=live.csv` streams telemetry rows while
//! training runs, `early_stop=P` stops after P evaluations without
//! val-F1 improvement, and `wall_budget=SECS` bounds real time.  The
//! arg parser is hand-rolled: the offline crate cache has no clap (see
//! Cargo.toml note).

use std::sync::Arc;

use digest::config::{RunConfig, ServeConfig};
use digest::exp::{run_experiment, Budget, Campaign};
use digest::graph::registry::{load, SPECS};
use digest::graph::stats::graph_stats;
use digest::graph::Split;
use digest::partition::{partition, quality, PartitionAlgo};
use digest::ps::checkpoint::Checkpoint;
use digest::serve::net::{run_load, Client, LoadedModel, Server, WIRE_VERSION};
use digest::serve::{self, InferenceEngine, InferenceModel, NodeQuery};
use digest::util::hist::{HistSummary, LatencyHistogram};
use digest::util::human_bytes;
use digest::util::json::Json;
use digest::{coordinator, eyre, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "usage: digest <list|generate|partition|train|ps-serve|worker|experiment|export|predict|bench-serve|serve|query> [args]\n\
     \n\
     digest list\n\
     digest generate --dataset <name> [--seed N]\n\
     digest partition --dataset <name> [--parts K] [--algo metis|bfs|random] [--seed N]\n\
     digest train [--config file.json] [--csv out.csv] [--distributed]\n\
     \x20             [--max-restarts N] [key=value ...]\n\
     \x20             (session knobs: save_to= save_every= load_from=\n\
     \x20              stream_csv= early_stop= wall_budget= export_best=;\n\
     \x20              --distributed spawns one worker process per partition\n\
     \x20              against an in-process ps-serve daemon and, with\n\
     \x20              --max-restarts, relaunches crashed workers; fault\n\
     \x20              knobs: dist.on_worker_loss=abort|wait|continue\n\
     \x20              dist.loss_grace= dist.io_timeout= dist.connect_retries=\n\
     \x20              dist.backoff_ms=, chaos plans via DIGEST_FAULT_PLAN;\n\
     \x20              mini-batch sampling: method=sampled model=sage\n\
     \x20              fanouts=10,25 batch_size=32 cache_nodes=1024 hidden=16)\n\
     digest ps-serve [--addr H:P] [--config file.json] [--csv out.csv] [key=value ...]\n\
     \x20             (training-plane daemon: hosts KVS + param server and\n\
     \x20              waits for `parts` workers; save_to= writes the final\n\
     \x20              checkpoint, sync runs only)\n\
     digest worker --part K --connect H:P [--config file.json] [key=value ...]\n\
     \x20             (one partition's training process; config must match\n\
     \x20              the daemon's bit for bit)\n\
     digest experiment <id|all> [--out-dir results] [--quick] [--seed N]\n\
     digest export <checkpoint.json> <model.json> [--seed N] [--name NAME]\n\
     \x20             [--artifact-dir DIR]\n\
     digest predict <model.json> [--nodes 0,1,2 | --split train|val|test|all]\n\
     \x20             [--topk K] [--seed N] [--threads T] [--out report.json]\n\
     \x20             [--fanouts 10,25]  (SAGE models: neighbor-sampled\n\
     \x20              seed-node inference instead of the full-graph forward)\n\
     digest bench-serve <model.json> [<model2.json> ...] [--iters N] [--threads T]\n\
     \x20             [--seed N] [--json out.json]\n\
     digest bench-serve --remote [--addr H:P] [--model NAME] [--clients C]\n\
     \x20             [--requests R] [--nodes 0,1,2] [--topk K] [--json out.json]\n\
     digest serve <model.json> [<model2.json> ...] [--addr H:P] [--max-conns N]\n\
     \x20             [--watch FILE] [--poll-ms MS] [--threads T] [--seed N]\n\
     digest query [--addr H:P] [--model NAME] [--nodes 0,1,2] [--topk K]\n\
     \x20             [--list] [--stats] [--reload [NAME]] [--shutdown]\n"
        .to_string()
}

/// Pull `--flag value` out of args; returns the value if present.
fn take_opt(args: &mut Vec<String>, flag: &str) -> Option<String> {
    if let Some(i) = args.iter().position(|a| a == flag) {
        if i + 1 < args.len() {
            let v = args.remove(i + 1);
            args.remove(i);
            return Some(v);
        }
        args.remove(i);
    }
    None
}

/// Pull a boolean `--flag` out of args.
fn take_flag(args: &mut Vec<String>, flag: &str) -> bool {
    if let Some(i) = args.iter().position(|a| a == flag) {
        args.remove(i);
        true
    } else {
        false
    }
}

fn run() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print!("{}", usage());
        return Ok(());
    }
    let cmd = args.remove(0);
    match cmd.as_str() {
        "list" => cmd_list(),
        "generate" => cmd_generate(args),
        "partition" => cmd_partition(args),
        "train" => cmd_train(args),
        "ps-serve" => cmd_ps_serve(args),
        "worker" => cmd_worker(args),
        "experiment" => cmd_experiment(args),
        "export" => cmd_export(args),
        "predict" => cmd_predict(args),
        "bench-serve" => cmd_bench_serve(args),
        "serve" => cmd_serve(args),
        "query" => cmd_query(args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(eyre!("unknown command {other:?}\n{}", usage())),
    }
}

fn cmd_list() -> Result<()> {
    println!("datasets:");
    for s in &SPECS {
        println!(
            "  {:12} (~{} nodes, {} classes, d={}, stands in for {}) -> artifact {}",
            s.name, s.nodes, s.n_class, s.d_in, s.paper_name, s.artifact
        );
    }
    match digest::runtime::Manifest::load("artifacts") {
        Ok(m) => {
            println!("\nartifacts ({}):", m.artifacts.len());
            let mut names: Vec<_> = m.artifacts.keys().collect();
            names.sort();
            for (name, kind) in names {
                println!("  {name} ({kind})");
            }
        }
        Err(_) => println!("\nartifacts: none built (run `make artifacts`)"),
    }
    println!("\nexperiments: {:?}", digest::exp::ALL_EXPERIMENTS);
    Ok(())
}

fn cmd_generate(mut args: Vec<String>) -> Result<()> {
    let dataset = take_opt(&mut args, "--dataset")
        .ok_or_else(|| eyre!("--dataset required"))?;
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let ds = load(&dataset, seed)?;
    ds.validate()?;
    let st = graph_stats(&ds.graph);
    println!("dataset {dataset} (seed {seed}):");
    println!("  nodes       {}", st.nodes);
    println!("  edges       {}", st.edges);
    println!("  avg degree  {:.2}", st.avg_degree);
    println!("  max degree  {}", st.max_degree);
    println!("  deg p50/p90/p99  {}/{}/{}", st.deg_p50, st.deg_p90, st.deg_p99);
    println!("  features    {} dims", ds.d_in());
    println!("  classes     {}", ds.n_class);
    let (tr, va, te) = (
        ds.nodes_in_split(digest::graph::Split::Train).len(),
        ds.nodes_in_split(digest::graph::Split::Val).len(),
        ds.nodes_in_split(digest::graph::Split::Test).len(),
    );
    println!("  split       {tr} train / {va} val / {te} test");
    Ok(())
}

fn cmd_partition(mut args: Vec<String>) -> Result<()> {
    let dataset = take_opt(&mut args, "--dataset")
        .ok_or_else(|| eyre!("--dataset required"))?;
    let parts: usize = take_opt(&mut args, "--parts").map_or(Ok(4), |s| {
        s.parse().map_err(|e| eyre!("--parts: {e}"))
    })?;
    let algo: PartitionAlgo = take_opt(&mut args, "--algo")
        .map_or(Ok(PartitionAlgo::Metis), |s| s.parse())?;
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let ds = load(&dataset, seed)?;
    let t0 = std::time::Instant::now();
    let p = partition(&ds.graph, parts, algo, seed);
    let elapsed = t0.elapsed();
    let q = quality::evaluate(&ds.graph, &p);
    println!("partitioned {dataset} into {parts} parts with {algo:?} in {elapsed:?}");
    println!("  sizes       {:?}", p.sizes());
    println!("  edge cut    {} ({:.2}% of edges)", q.edge_cut, 100.0 * q.cut_ratio);
    println!("  balance     {:.3}", q.balance);
    println!("  halo sizes  {:?}", q.halo_sizes);
    println!("  halo ratio  {:.1}%", 100.0 * q.avg_halo_ratio);
    Ok(())
}

fn cmd_train(mut args: Vec<String>) -> Result<()> {
    let distributed = take_flag(&mut args, "--distributed");
    let config_path = take_opt(&mut args, "--config");
    let mut cfg = match &config_path {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| eyre!("reading {path}: {e}"))?;
            RunConfig::from_json(&Json::parse(&text)?)?
        }
        None => RunConfig::default(),
    };
    let csv_out = take_opt(&mut args, "--csv");
    // legacy flags; save_to= / load_from= overrides are the same knobs
    if let Some(path) = take_opt(&mut args, "--save") {
        cfg.save_to = Some(path);
    }
    if let Some(path) = take_opt(&mut args, "--load") {
        cfg.load_from = Some(path);
    }
    let max_restarts: usize = take_opt(&mut args, "--max-restarts").map_or(Ok(0), |s| {
        s.parse().map_err(|e| eyre!("--max-restarts: {e}"))
    })?;
    if max_restarts > 0 && !distributed {
        return Err(eyre!("--max-restarts only applies to --distributed runs"));
    }
    for kv in &args {
        cfg.apply_override(kv)?;
    }
    if distributed {
        // forward the same config surface to the worker processes so
        // every process derives the identical RunConfig
        let mut forward = Vec::new();
        if let Some(path) = &config_path {
            forward.push("--config".to_string());
            forward.push(path.clone());
        }
        forward.extend(args.iter().cloned());
        return run_distributed(cfg, forward, csv_out, max_restarts);
    }
    println!(
        "training {} / {} with {} on {} workers (N={}, epochs={}, lr={})",
        cfg.dataset,
        cfg.model.as_str(),
        cfg.method.as_str(),
        cfg.parts,
        cfg.sync_interval,
        cfg.epochs,
        cfg.lr
    );
    let mut ctx = coordinator::TrainContext::new(cfg)?;
    let loaded = coordinator::prepare_resume(&mut ctx)?;
    if let Some(ckpt) = &loaded {
        println!(
            "{} {} (epoch {}, best val F1 {:.4})",
            if ckpt.state.is_some() {
                "resuming training state from"
            } else {
                "warm-starting params from v1 checkpoint"
            },
            ctx.cfg.load_from.as_deref().unwrap_or("?"),
            ckpt.epoch,
            ckpt.best_val_f1
        );
    }
    let mut session = coordinator::session_from_checkpoint(&ctx, loaded.as_ref())?;
    let mut driver = coordinator::Driver::from_config(&ctx.cfg)?;
    let res = driver.run(session.as_mut())?;
    if let Some(reason) = driver.stop_reason() {
        println!("stopped early: {reason}");
    }
    if let Some(path) = &ctx.cfg.save_to {
        println!("training state saved to {path} (resume with load_from={path})");
    }
    println!("\nresults:");
    println!("  best val F1    {:.4}", res.best_val_f1);
    println!("  final val F1   {:.4}", res.final_val_f1);
    println!("  final test F1  {:.4}", res.final_test_f1);
    println!("  virtual time   {:.3}s ({:.4}s/epoch)", res.total_vtime, res.avg_epoch_vtime());
    println!("  wall time      {:.1}s ({} worker threads)", res.total_wall, res.threads);
    println!(
        "  KVS traffic    {} ({} pulls, {} pushes, {} misses)",
        human_bytes(res.kvs.total_bytes()),
        res.kvs.pulls,
        res.kvs.pushes,
        res.kvs.misses
    );
    if res.delay.updates > 0 && res.method == "digest-a" {
        println!(
            "  async delay    mean {:.2}, max {}",
            res.delay.mean_delay(),
            res.delay.max_delay
        );
    }
    if let Some(path) = csv_out {
        std::fs::write(&path, res.to_csv()).map_err(|e| eyre!("writing {path}: {e}"))?;
        println!("  timeline CSV   {path}");
    }
    Ok(())
}

/// `digest train --distributed` — one worker OS process per partition
/// against an in-process `ps-serve` daemon.  The parent binds an
/// ephemeral port, re-execs itself `parts` times as `digest worker`,
/// and serves the run on the main thread.  With `--max-restarts N`, a
/// supervisor relaunches crashed worker processes (up to N total) —
/// under `dist.on_worker_loss=wait` the replacement resumes from the
/// daemon-parked snapshot and the run carries on.
fn run_distributed(
    cfg: RunConfig,
    forward: Vec<String>,
    csv_out: Option<String>,
    max_restarts: usize,
) -> Result<()> {
    if cfg.load_from.is_some() {
        return Err(eyre!("--distributed does not support resume (load_from) yet"));
    }
    println!(
        "distributed training {} / {} with {} across {} processes (N={}, epochs={})",
        cfg.dataset,
        cfg.model.as_str(),
        cfg.method.as_str(),
        cfg.parts,
        cfg.sync_interval,
        cfg.epochs
    );
    let save_to = cfg.save_to.clone();
    let parts = cfg.parts;
    let on_loss = cfg.dist.on_worker_loss;
    let server = coordinator::dist::PsServer::bind(cfg, "127.0.0.1:0", save_to.clone())?;
    let addr = server.local_addr()?.to_string();
    let exe = std::env::current_exe().map_err(|e| eyre!("current_exe: {e}"))?;
    let spawn_worker = |part: usize, relaunch: bool| -> Result<std::process::Child> {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .arg("--part")
            .arg(part.to_string())
            .arg("--connect")
            .arg(&addr)
            .args(&forward);
        if relaunch {
            // the fault plan applies to the first incarnation only: a
            // replacement restarts its frame counter at 0, so an
            // inherited `down` rule would just kill it again
            cmd.env_remove(coordinator::dist::FAULT_PLAN_ENV);
        }
        cmd.spawn().map_err(|e| eyre!("spawning worker {part}: {e}"))
    };
    let mut spawned: Vec<Option<std::process::Child>> = Vec::new();
    for part in 0..parts {
        spawned.push(Some(spawn_worker(part, false)?));
    }
    let children = std::sync::Mutex::new(spawned);
    let stop = std::sync::atomic::AtomicBool::new(false);
    let failures: std::sync::Mutex<Vec<String>> = std::sync::Mutex::new(Vec::new());
    // lint:allow(D003, worker-process supervisor: restarts crashed children while the daemon serves on the main thread)
    let outcome = std::thread::scope(|s| {
        if max_restarts > 0 {
            s.spawn(|| {
                let mut budget = max_restarts;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    {
                        let mut kids = digest::util::lock_unpoisoned(&children);
                        for (part, slot) in kids.iter_mut().enumerate() {
                            let child = match slot.as_mut() {
                                Some(c) => c,
                                None => continue,
                            };
                            match child.try_wait() {
                                Ok(None) => {}
                                Ok(Some(status)) if status.success() => *slot = None,
                                Ok(Some(status)) => {
                                    if budget > 0 {
                                        budget -= 1;
                                        eprintln!(
                                            "worker {part} exited with {status}; \
                                             relaunching ({budget} restart(s) left)"
                                        );
                                        match spawn_worker(part, true) {
                                            Ok(c) => *slot = Some(c),
                                            Err(e) => {
                                                digest::util::lock_unpoisoned(&failures)
                                                    .push(format!("{e}"));
                                                *slot = None;
                                            }
                                        }
                                    } else {
                                        digest::util::lock_unpoisoned(&failures).push(
                                            format!(
                                                "worker {part} exited with {status} \
                                                 (restart budget spent)"
                                            ),
                                        );
                                        *slot = None;
                                    }
                                }
                                Err(e) => {
                                    digest::util::lock_unpoisoned(&failures)
                                        .push(format!("polling worker {part}: {e}"));
                                    *slot = None;
                                }
                            }
                        }
                    }
                    std::thread::sleep(std::time::Duration::from_millis(200));
                }
            });
        }
        let outcome = server.run();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        outcome
    });
    // reap the workers whether the daemon succeeded or not
    let mut worker_err: Option<anyhow::Error> = None;
    let spawned = children.into_inner().unwrap_or_else(|p| p.into_inner());
    for (part, slot) in spawned.into_iter().enumerate() {
        let mut child = match slot {
            Some(c) => c,
            None => continue,
        };
        if outcome.is_err() {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                worker_err.get_or_insert(eyre!("worker {part} exited with {status}"));
            }
            Err(e) => {
                worker_err.get_or_insert(eyre!("waiting for worker {part}: {e}"));
            }
        }
    }
    for f in failures.into_inner().unwrap_or_else(|p| p.into_inner()) {
        worker_err.get_or_insert(eyre!("{f}"));
    }
    let outcome = outcome?;
    if let Some(e) = worker_err {
        // a departed worker is an expected casualty under
        // on_worker_loss=continue: the daemon completed without it
        if on_loss == digest::config::LossPolicy::Continue {
            println!("note: {e} (run continued without it)");
        } else {
            return Err(e);
        }
    }
    if let Some(path) = &save_to {
        println!("training state saved to {path} (resume with load_from={path})");
    }
    print_dist_outcome(&outcome, csv_out)
}

fn print_dist_outcome(
    outcome: &coordinator::dist::DistOutcome,
    csv_out: Option<String>,
) -> Result<()> {
    println!("\nresults:");
    println!("  best val F1    {:.4}", outcome.best_val_f1);
    println!("  final val F1   {:.4}", outcome.final_val_f1);
    println!("  final test F1  {:.4}", outcome.final_test_f1);
    println!("  virtual time   {:.3}s", outcome.total_vtime);
    println!(
        "  KVS traffic    {} ({} pulls, {} pushes, {} misses)",
        human_bytes(outcome.kvs.total_bytes()),
        outcome.kvs.pulls,
        outcome.kvs.pushes,
        outcome.kvs.misses
    );
    println!(
        "  wire traffic   {} over {} updates",
        human_bytes(outcome.wire_bytes),
        outcome.updates
    );
    if outcome.leases_lost > 0 || outcome.wire_retries > 0 {
        println!(
            "  fault recovery {} lease(s) lost, {} frame(s) replayed from the reply log",
            outcome.leases_lost, outcome.wire_retries
        );
    }
    if let Some(path) = csv_out {
        let mut s = String::from(coordinator::LogPoint::CSV_HEADER);
        for p in &outcome.points {
            s.push_str(&p.csv_row());
        }
        std::fs::write(&path, s).map_err(|e| eyre!("writing {path}: {e}"))?;
        println!("  timeline CSV   {path}");
    }
    Ok(())
}

/// Shared config parsing for the two distributed-process entry points:
/// `--config file.json` plus `key=value` overrides, identical to
/// `digest train` so all processes derive the same `RunConfig`.
fn dist_config(args: &mut Vec<String>) -> Result<RunConfig> {
    let mut cfg = match take_opt(args, "--config") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| eyre!("reading {path}: {e}"))?;
            RunConfig::from_json(&Json::parse(&text)?)?
        }
        None => RunConfig::default(),
    };
    for kv in args.iter() {
        cfg.apply_override(kv)?;
    }
    Ok(cfg)
}

/// `digest ps-serve` — stand-alone training-plane daemon.  Blocks until
/// `parts` workers connect and the run completes.
fn cmd_ps_serve(mut args: Vec<String>) -> Result<()> {
    let addr = take_opt(&mut args, "--addr").unwrap_or_else(|| "127.0.0.1:7878".into());
    let csv_out = take_opt(&mut args, "--csv");
    let cfg = dist_config(&mut args)?;
    let save_to = cfg.save_to.clone();
    let server = coordinator::dist::PsServer::bind(cfg, &addr, save_to.clone())?;
    let local = server.local_addr()?;
    println!("ps-serve listening on {local}; waiting for workers");
    let outcome = server.run()?;
    if let Some(path) = &save_to {
        println!("training state saved to {path}");
    }
    print_dist_outcome(&outcome, csv_out)
}

/// `digest worker` — one partition's training process.
fn cmd_worker(mut args: Vec<String>) -> Result<()> {
    let part: usize = take_opt(&mut args, "--part")
        .ok_or_else(|| eyre!("--part required"))?
        .parse()
        .map_err(|e| eyre!("--part: {e}"))?;
    let addr = take_opt(&mut args, "--connect")
        .ok_or_else(|| eyre!("--connect required"))?;
    let cfg = dist_config(&mut args)?;
    let run = coordinator::dist::run_worker(&cfg, part, &addr)?;
    println!(
        "worker {} done: {} local epochs, {} on the wire, final val F1 {:.4} / test {:.4}",
        run.part,
        run.epochs_run,
        human_bytes(run.wire_bytes),
        run.final_val_f1,
        run.final_test_f1
    );
    if run.reconnects > 0 {
        println!("  ({} mid-run reconnect(s))", run.reconnects);
    }
    Ok(())
}

/// `digest export <ckpt> <model>` — turn a (v1 or v2) training
/// checkpoint into a sealed, servable `digest-model-v1` artifact.  The
/// dataset is derived from the checkpoint's artifact name; `--seed`
/// must match the training run's dataset seed (default 42) because the
/// model fingerprints the generated graph instance.
fn cmd_export(mut args: Vec<String>) -> Result<()> {
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let artifact_dir =
        take_opt(&mut args, "--artifact-dir").unwrap_or_else(|| "artifacts".into());
    let name = take_opt(&mut args, "--name");
    if args.len() != 2 {
        return Err(eyre!(
            "export needs <checkpoint.json> <model-out.json>\n{}",
            usage()
        ));
    }
    let (ckpt_path, out_path) = (&args[0], &args[1]);
    let ckpt = Checkpoint::load(ckpt_path)?;
    let (dspec, kind) = serve::dataset_for_artifact(&ckpt.artifact)?;
    let manifest = digest::runtime::Manifest::load(&artifact_dir)?;
    let spec = manifest.get(&ckpt.artifact, "train")?;
    let ds = load(dspec.name, seed)?;
    if ckpt.graph_fingerprint.is_none() {
        eprintln!(
            "warning: checkpoint records no graph fingerprint (pre-serve file); \
             trusting --seed {seed} to regenerate the training graph"
        );
    }
    let name = name.unwrap_or_else(|| format!("{}-e{}", ckpt.artifact, ckpt.epoch));
    let model = InferenceModel::from_checkpoint(&name, &ckpt, spec, &ds, dspec.name, seed)?;
    model.save(out_path)?;
    println!(
        "exported model {:?}: {} {} dims {:?}",
        model.name(),
        dspec.name,
        kind.as_str(),
        model.dims()
    );
    println!(
        "  from        {ckpt_path} (epoch {}, best val F1 {:.4})",
        ckpt.epoch, ckpt.best_val_f1
    );
    println!(
        "  graph       {} seed {seed}, fingerprint {:#018x}",
        dspec.name,
        model.graph_fingerprint()
    );
    println!("  written to  {out_path}");
    Ok(())
}

fn parse_node_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|p| p.trim().parse::<usize>().map_err(|e| eyre!("--nodes {p:?}: {e}")))
        .collect()
}

/// `digest predict <model>` — serve predictions from an exported model
/// through a fresh [`InferenceEngine`] (no training stack involved).
fn cmd_predict(mut args: Vec<String>) -> Result<()> {
    let topk: usize = take_opt(&mut args, "--topk").map_or(Ok(3), |s| {
        s.parse().map_err(|e| eyre!("--topk: {e}"))
    })?;
    let topk = topk.max(1);
    let threads: usize = take_opt(&mut args, "--threads").map_or(Ok(0), |s| {
        s.parse().map_err(|e| eyre!("--threads: {e}"))
    })?;
    let seed_opt: Option<u64> = match take_opt(&mut args, "--seed") {
        Some(s) => Some(s.parse().map_err(|e| eyre!("--seed: {e}"))?),
        None => None,
    };
    let nodes_opt = take_opt(&mut args, "--nodes");
    let split_opt = take_opt(&mut args, "--split");
    let out_opt = take_opt(&mut args, "--out");
    let fanouts_opt: Option<Vec<usize>> = match take_opt(&mut args, "--fanouts") {
        Some(s) => Some(
            s.split(',')
                .map(|t| t.trim().parse().map_err(|e| eyre!("--fanouts {t:?}: {e}")))
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    if nodes_opt.is_some() && split_opt.is_some() {
        return Err(eyre!(
            "--nodes and --split are mutually exclusive (pass one node selection)"
        ));
    }
    if args.len() != 1 {
        return Err(eyre!("predict needs <model.json>\n{}", usage()));
    }
    let model = InferenceModel::load(&args[0])?;
    let seed = seed_opt.unwrap_or_else(|| model.seed());
    let ds = Arc::new(load(model.dataset(), seed)?);
    let engine = InferenceEngine::new(ds.clone()).with_threads(threads);
    let query = match (nodes_opt, split_opt.as_deref()) {
        (Some(list), _) => NodeQuery::nodes(parse_node_list(&list)?),
        (None, Some("all")) => NodeQuery::full(),
        (None, split) => {
            // default: the validation split
            let s = match split.unwrap_or("val") {
                "train" => Split::Train,
                "val" => Split::Val,
                "test" => Split::Test,
                other => return Err(eyre!("--split {other:?} (train|val|test|all)")),
            };
            NodeQuery::nodes(ds.nodes_in_split(s))
        }
    }
    .with_top_k(topk);
    let query = match fanouts_opt {
        Some(f) => query.with_fanouts(f),
        None => query,
    };
    let pred = engine.predict(&model, &query)?;
    println!(
        "model {:?} ({} {}, exported at epoch {}, val F1 {:.4})",
        model.name(),
        model.dataset(),
        model.kind().as_str(),
        model.epoch(),
        model.val_f1()
    );
    let correct = pred
        .nodes
        .iter()
        .zip(&pred.classes)
        .filter(|&(&v, &c)| ds.labels[v] as usize == c)
        .count();
    println!(
        "predicted {} node(s); agreement with dataset labels {:.4} ({correct}/{})",
        pred.nodes.len(),
        correct as f64 / pred.nodes.len() as f64,
        pred.nodes.len()
    );
    for (i, &v) in pred.nodes.iter().take(10).enumerate() {
        let tk: Vec<String> = pred.top_k[i]
            .iter()
            .map(|&(c, l)| format!("class {c} ({l:.3})"))
            .collect();
        println!("  node {v:>6}: {}", tk.join(", "));
    }
    if pred.nodes.len() > 10 {
        println!("  ... {} more node(s)", pred.nodes.len() - 10);
    }
    if let Some(path) = out_opt {
        let rows: Vec<Json> = pred
            .nodes
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                Json::obj(vec![
                    ("node", Json::num(v as f64)),
                    ("class", Json::num(pred.classes[i] as f64)),
                    (
                        "topk",
                        Json::Arr(
                            pred.top_k[i]
                                .iter()
                                .map(|&(c, l)| {
                                    Json::obj(vec![
                                        ("class", Json::num(c as f64)),
                                        ("logit", Json::num(l as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("model", Json::str(model.name())),
            ("dataset", Json::str(model.dataset())),
            ("predictions", Json::Arr(rows)),
        ]);
        std::fs::write(&path, j.to_string()).map_err(|e| eyre!("writing {path}: {e}"))?;
        println!("  report JSON   {path}");
    }
    Ok(())
}

/// One `bench-serve` result row; in-process and `--remote` runs emit
/// the same p50/p90/p99 schema (printed and in `--json` output,
/// matching the `BENCH_serve.json` baseline format).
struct BenchRow {
    mode: &'static str,
    target: String,
    /// What one histogram sample measures ("predict", "batch", "request").
    unit: &'static str,
    clients: usize,
    summary: HistSummary,
    throughput_rps: f64,
    /// Wire cost per completed request; None for in-process rows.
    bytes_out_per_req: Option<f64>,
    bytes_in_per_req: Option<f64>,
}

impl BenchRow {
    fn print(&self) {
        let s = &self.summary;
        println!(
            "  {:<18} n={:<6} mean {:8.3} ms  p50 {:8.3}  p90 {:8.3}  p99 {:8.3}  max {:8.3}",
            self.mode,
            s.count,
            s.mean * 1e3,
            s.p50 * 1e3,
            s.p90 * 1e3,
            s.p99 * 1e3,
            s.max * 1e3
        );
        println!(
            "  {:<18} {:10.1} {}(s)/s over {} client(s)",
            "", self.throughput_rps, self.unit, self.clients
        );
        if let (Some(out), Some(inn)) = (self.bytes_out_per_req, self.bytes_in_per_req) {
            println!(
                "  {:<18} wire: {:.0} B out + {:.0} B in per request",
                "", out, inn
            );
        }
    }

    fn to_json(&self) -> Json {
        let s = &self.summary;
        let opt = |v: Option<f64>| v.map_or(Json::Null, Json::num);
        Json::obj(vec![
            ("mode", Json::str(self.mode)),
            ("target", Json::str(self.target.as_str())),
            ("unit", Json::str(self.unit)),
            ("clients", Json::uint(self.clients as u64)),
            ("requests", Json::uint(s.count)),
            ("mean_ms", Json::num(s.mean * 1e3)),
            ("p50_ms", Json::num(s.p50 * 1e3)),
            ("p90_ms", Json::num(s.p90 * 1e3)),
            ("p99_ms", Json::num(s.p99 * 1e3)),
            ("max_ms", Json::num(s.max * 1e3)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("bytes_out_per_req", opt(self.bytes_out_per_req)),
            ("bytes_in_per_req", opt(self.bytes_in_per_req)),
        ])
    }
}

/// Write bench rows in the `BENCH_serve.json` baseline schema.
fn write_bench_serve_json(path: &str, rows: &[BenchRow]) -> Result<()> {
    let j = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("schema", Json::str("digest-bench-serve-v1")),
        ("rows", Json::Arr(rows.iter().map(BenchRow::to_json).collect())),
    ]);
    std::fs::write(path, j.to_string()).map_err(|e| eyre!("writing {path}: {e}"))?;
    println!("  bench JSON   {path}");
    Ok(())
}

/// `digest bench-serve <model>...` — single interleaved predicts vs one
/// batched `predict_many` over the same engine; asserts the warm engine
/// performs zero structure rebuilds either way.  With `--remote`, a
/// concurrent load generator against a running `digest serve` daemon;
/// both variants report the same latency-histogram schema.
fn cmd_bench_serve(mut args: Vec<String>) -> Result<()> {
    let json_out = take_opt(&mut args, "--json");
    if take_flag(&mut args, "--remote") {
        return cmd_bench_serve_remote(args, json_out);
    }
    let iters: usize = take_opt(&mut args, "--iters").map_or(Ok(50), |s| {
        s.parse().map_err(|e| eyre!("--iters: {e}"))
    })?;
    let threads: usize = take_opt(&mut args, "--threads").map_or(Ok(0), |s| {
        s.parse().map_err(|e| eyre!("--threads: {e}"))
    })?;
    let seed_opt: Option<u64> = match take_opt(&mut args, "--seed") {
        Some(s) => Some(s.parse().map_err(|e| eyre!("--seed: {e}"))?),
        None => None,
    };
    if args.is_empty() {
        return Err(eyre!("bench-serve needs at least one <model.json>\n{}", usage()));
    }
    let models: Vec<InferenceModel> = args
        .iter()
        .map(InferenceModel::load)
        .collect::<Result<_>>()?;
    for m in &models[1..] {
        if m.graph_fingerprint() != models[0].graph_fingerprint() {
            return Err(eyre!(
                "models {:?} and {:?} were exported for different graphs",
                models[0].name(),
                m.name()
            ));
        }
    }
    let seed = seed_opt.unwrap_or_else(|| models[0].seed());
    let ds = Arc::new(load(models[0].dataset(), seed)?);
    let n_nodes = ds.n();
    let engine = InferenceEngine::new(ds).with_threads(threads);
    let q = NodeQuery::full();
    let reqs: Vec<(&InferenceModel, &NodeQuery)> = models.iter().map(|m| (m, &q)).collect();
    engine.predict_many(&reqs)?; // warmup: builds structures + scratch
    let warm = engine.stats();
    let mut single_hist = LatencyHistogram::new();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        for m in &models {
            let t = std::time::Instant::now();
            engine.predict(m, &q)?;
            single_hist.record(t.elapsed().as_secs_f64());
        }
    }
    let single = t0.elapsed();
    let mut batched_hist = LatencyHistogram::new();
    let t1 = std::time::Instant::now();
    for _ in 0..iters {
        let t = std::time::Instant::now();
        engine.predict_many(&reqs)?;
        batched_hist.record(t.elapsed().as_secs_f64());
    }
    let batched = t1.elapsed();
    let steady = engine.stats();
    if steady.structure_builds != warm.structure_builds {
        return Err(eyre!(
            "structure rebuilt after warmup ({} -> {})",
            warm.structure_builds,
            steady.structure_builds
        ));
    }
    let target = format!("{} x{} models", models[0].dataset(), models.len());
    let rows = [
        BenchRow {
            mode: "in-process-single",
            target: target.clone(),
            unit: "predict",
            clients: 1,
            summary: single_hist.summary(),
            throughput_rps: single_hist.count() as f64 / single.as_secs_f64().max(1e-12),
            bytes_out_per_req: None,
            bytes_in_per_req: None,
        },
        BenchRow {
            mode: "in-process-batched",
            target,
            unit: "batch",
            clients: 1,
            summary: batched_hist.summary(),
            throughput_rps: batched_hist.count() as f64 / batched.as_secs_f64().max(1e-12),
            bytes_out_per_req: None,
            bytes_in_per_req: None,
        },
    ];
    println!(
        "bench-serve: {} model(s) over {} ({n_nodes} nodes), {iters} iters, threads={threads}",
        models.len(),
        models[0].dataset()
    );
    for row in &rows {
        row.print();
    }
    println!(
        "  ({:.2}x batched vs single per prediction)",
        single.as_secs_f64() / batched.as_secs_f64().max(1e-12)
    );
    println!(
        "  engine   {} structure build(s), {} scratch alloc(s), {} forwards, {} predictions",
        steady.structure_builds, steady.scratch_allocs, steady.forwards, steady.predictions
    );
    println!("  zero structure rebuilds after warmup: OK");
    if let Some(path) = json_out {
        write_bench_serve_json(&path, &rows)?;
    }
    Ok(())
}

/// `digest bench-serve --remote` — drive a running `digest serve`
/// daemon with N concurrent client threads and report the merged
/// latency histogram plus bytes on the wire per request.
fn cmd_bench_serve_remote(mut args: Vec<String>, json_out: Option<String>) -> Result<()> {
    let addr = take_opt(&mut args, "--addr").unwrap_or_else(|| ServeConfig::default().addr);
    let clients: usize = take_opt(&mut args, "--clients").map_or(Ok(4), |s| {
        s.parse().map_err(|e| eyre!("--clients: {e}"))
    })?;
    let requests: usize = take_opt(&mut args, "--requests").map_or(Ok(50), |s| {
        s.parse().map_err(|e| eyre!("--requests: {e}"))
    })?;
    let topk: usize = take_opt(&mut args, "--topk").map_or(Ok(3), |s| {
        s.parse().map_err(|e| eyre!("--topk: {e}"))
    })?;
    let nodes_opt = take_opt(&mut args, "--nodes");
    let model_opt = take_opt(&mut args, "--model");
    if !args.is_empty() {
        return Err(eyre!("bench-serve --remote: unexpected args {args:?}\n{}", usage()));
    }
    let model = match model_opt {
        Some(m) => m,
        None => sole_remote_model(&addr)?,
    };
    let query = match &nodes_opt {
        Some(list) => NodeQuery::nodes(parse_node_list(list)?),
        None => NodeQuery::full(),
    }
    .with_top_k(topk);
    println!(
        "bench-serve --remote: {clients} client(s) x {requests} request(s) \
         against {addr} (model {model:?})"
    );
    let report = run_load(&addr, &model, &query, clients, requests)?;
    if report.errors > 0 {
        println!(
            "  WARNING: {} request(s) failed (first: {})",
            report.errors,
            report.first_error.as_deref().unwrap_or("?")
        );
    }
    let row = BenchRow {
        mode: "remote",
        target: addr.clone(),
        unit: "request",
        clients,
        summary: report.hist.summary(),
        throughput_rps: report.throughput_rps(),
        bytes_out_per_req: Some(report.bytes_out_per_req()),
        bytes_in_per_req: Some(report.bytes_in_per_req()),
    };
    row.print();
    println!("{}", report.hist.ascii(40));
    if let Some(path) = json_out {
        write_bench_serve_json(&path, &[row])?;
    }
    if report.errors > 0 && report.completed == 0 {
        return Err(eyre!("every request failed"));
    }
    Ok(())
}

/// Ask the daemon for its model list and return the single model's
/// name (error if there are zero or several — pass `--model` then).
fn sole_remote_model(addr: &str) -> Result<String> {
    let mut probe = Client::connect(addr)?;
    let models = probe.list_models()?;
    match models.len() {
        1 => Ok(models[0].name.clone()),
        0 => Err(eyre!("daemon at {addr} serves no models")),
        _ => Err(eyre!(
            "daemon serves {} models — pick one with --model: {:?}",
            models.len(),
            models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>()
        )),
    }
}

/// `digest serve <models...>` — the long-running TCP inference daemon
/// (`serve::net::Server`): bounded concurrency, `digest-wire-v1`
/// protocol, optional hot rollover of the `--watch` file.
fn cmd_serve(mut args: Vec<String>) -> Result<()> {
    let mut cfg = ServeConfig::default();
    if let Some(v) = take_opt(&mut args, "--addr") {
        cfg.addr = v;
    }
    if let Some(v) = take_opt(&mut args, "--max-conns") {
        cfg.max_conns = v.parse().map_err(|e| eyre!("--max-conns: {e}"))?;
    }
    cfg.watch = take_opt(&mut args, "--watch");
    if let Some(v) = take_opt(&mut args, "--poll-ms") {
        cfg.poll_ms = v.parse().map_err(|e| eyre!("--poll-ms: {e}"))?;
    }
    if let Some(v) = take_opt(&mut args, "--threads") {
        cfg.threads = v.parse().map_err(|e| eyre!("--threads: {e}"))?;
    }
    let seed_opt: Option<u64> = match take_opt(&mut args, "--seed") {
        Some(s) => Some(s.parse().map_err(|e| eyre!("--seed: {e}"))?),
        None => None,
    };
    if args.is_empty() {
        // `digest serve --watch best.json` alone works once the file
        // exists: serve the watched model from the start
        match &cfg.watch {
            Some(w) if std::path::Path::new(w).is_file() => args.push(w.clone()),
            _ => {
                return Err(eyre!(
                    "serve needs at least one <model.json> (or --watch pointing at an \
                     existing model file)\n{}",
                    usage()
                ))
            }
        }
    }
    let mut models = Vec::with_capacity(args.len());
    for path in &args {
        models.push((InferenceModel::load(path)?, path.clone()));
    }
    for (m, _) in &models[1..] {
        if m.graph_fingerprint() != models[0].0.graph_fingerprint() {
            return Err(eyre!(
                "models {:?} and {:?} were exported for different graphs",
                models[0].0.name(),
                m.name()
            ));
        }
    }
    let seed = seed_opt.unwrap_or_else(|| models[0].0.seed());
    let ds = Arc::new(load(models[0].0.dataset(), seed)?);
    let engine = Arc::new(InferenceEngine::new(ds).with_threads(cfg.threads));
    let loaded: Vec<LoadedModel> = models
        .into_iter()
        .map(|(model, path)| LoadedModel {
            model,
            source: Some(path),
        })
        .collect();
    let n_models = loaded.len();
    let server = Server::bind(&cfg, engine, loaded)?;
    let addr = server.local_addr()?;
    println!(
        "digest serve: {n_models} model(s) on {addr} ({WIRE_VERSION}, max-conns {}{})",
        cfg.max_conns,
        match &cfg.watch {
            Some(w) => format!(", watching {w} every {}ms", cfg.poll_ms),
            None => String::new(),
        }
    );
    println!("  stop with: digest query --addr {addr} --shutdown");
    let stats = server.run()?;
    println!(
        "digest serve: drained. {} accepted, {} served, {} busy-rejected, {} reload(s)",
        stats.accepted, stats.served, stats.busy_rejected, stats.reloads
    );
    println!(
        "  wire: {} in, {} out; {} app error(s), {} frame error(s)",
        human_bytes(stats.bytes_in),
        human_bytes(stats.bytes_out),
        stats.app_errors,
        stats.frame_errors
    );
    Ok(())
}

/// `digest query` — remote client for a running daemon: predict over
/// TCP plus the admin verbs (`--list`, `--stats`, `--reload`,
/// `--shutdown`).
fn cmd_query(mut args: Vec<String>) -> Result<()> {
    let addr = take_opt(&mut args, "--addr").unwrap_or_else(|| ServeConfig::default().addr);
    let list = take_flag(&mut args, "--list");
    let stats = take_flag(&mut args, "--stats");
    let shutdown = take_flag(&mut args, "--shutdown");
    // --reload takes an OPTIONAL model name: bare --reload = all
    // file-backed models
    let reload: Option<String> = match args.iter().position(|a| a == "--reload") {
        Some(i) => {
            args.remove(i);
            if i < args.len() && !args[i].starts_with("--") {
                Some(args.remove(i))
            } else {
                Some(String::new())
            }
        }
        None => None,
    };
    let model_opt = take_opt(&mut args, "--model");
    let nodes_opt = take_opt(&mut args, "--nodes");
    let topk: usize = take_opt(&mut args, "--topk").map_or(Ok(3), |s| {
        s.parse().map_err(|e| eyre!("--topk: {e}"))
    })?;
    if !args.is_empty() {
        return Err(eyre!("query: unexpected args {args:?}\n{}", usage()));
    }
    let admin = list || stats || shutdown || reload.is_some();
    let do_predict = !admin || model_opt.is_some() || nodes_opt.is_some();
    let mut client = Client::connect(&addr)?;
    if list {
        let models = client.list_models()?;
        println!("{} model(s) at {addr}:", models.len());
        for m in &models {
            println!(
                "  {:24} {} {}  dims {:?}  epoch {}  val F1 {:.4}  graph {:#018x}",
                m.name, m.dataset, m.kind, m.dims, m.epoch, m.val_f1, m.graph_fingerprint
            );
        }
    }
    if let Some(name) = reload {
        let reloaded = client.reload(&name)?;
        println!("reloaded {} model(s): {reloaded:?}", reloaded.len());
    }
    if stats {
        let s = client.stats()?;
        println!("daemon stats at {addr}:");
        println!(
            "  conns    {} active / {} max; {} accepted, {} busy-rejected",
            s.active_conns, s.max_conns, s.accepted, s.busy_rejected
        );
        println!(
            "  traffic  {} served, {} in, {} out, {} app error(s), {} frame error(s)",
            s.served,
            human_bytes(s.bytes_in),
            human_bytes(s.bytes_out),
            s.app_errors,
            s.frame_errors
        );
        println!("  models   {} loaded, {} reload(s)", s.models, s.reloads);
        println!(
            "  engine   {} structure build(s), {} scratch alloc(s), {} forwards, \
             {} predictions, {} batches",
            s.engine.structure_builds,
            s.engine.scratch_allocs,
            s.engine.forwards,
            s.engine.predictions,
            s.engine.batches
        );
    }
    if do_predict {
        let model = match model_opt {
            Some(m) => m,
            None => sole_remote_model(&addr)?,
        };
        let query = match &nodes_opt {
            Some(listing) => NodeQuery::nodes(parse_node_list(listing)?),
            None => NodeQuery::full(),
        }
        .with_top_k(topk.max(1));
        let t0 = std::time::Instant::now();
        let pred = client.predict(&model, &query)?;
        let rtt = t0.elapsed();
        println!(
            "model {:?} via {addr}: {} node(s) in {:.2} ms",
            pred.model,
            pred.nodes.len(),
            rtt.as_secs_f64() * 1e3
        );
        for (i, &v) in pred.nodes.iter().take(10).enumerate() {
            let tk: Vec<String> = pred.top_k[i]
                .iter()
                .map(|&(c, l)| format!("class {c} ({l:.3})"))
                .collect();
            println!("  node {v:>6}: {}", tk.join(", "));
        }
        if pred.nodes.len() > 10 {
            println!("  ... {} more node(s)", pred.nodes.len() - 10);
        }
        println!(
            "  wire: {} B out, {} B in this connection",
            client.bytes_out(),
            client.bytes_in()
        );
    }
    if shutdown {
        client.shutdown()?;
        println!("daemon at {addr} acknowledged shutdown (drain + exit)");
    }
    Ok(())
}

fn cmd_experiment(mut args: Vec<String>) -> Result<()> {
    let out_dir = take_opt(&mut args, "--out-dir").unwrap_or_else(|| "results".into());
    let quick = take_flag(&mut args, "--quick");
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let id = args
        .first()
        .ok_or_else(|| eyre!("experiment id required (or 'all')"))?
        .clone();
    let budget = if quick { Budget::quick() } else { Budget::full() };
    let mut campaign = Campaign::new(&out_dir, budget, seed)?;
    let t0 = std::time::Instant::now();
    run_experiment(&id, &mut campaign)?;
    println!("experiment {id} done in {:?}; outputs in {out_dir}/", t0.elapsed());
    Ok(())
}
