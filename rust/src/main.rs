//! `digest` — CLI for the DIGEST distributed GNN training framework.
//!
//! ```text
//! digest list                               # datasets + artifacts
//! digest generate --dataset arxiv-s         # dataset stats
//! digest partition --dataset arxiv-s --parts 4 --algo metis
//! digest train [--config run.json] [key=value ...] [--csv out.csv]
//! digest experiment <id|all> [--out-dir results] [--quick] [--seed N]
//! ```
//!
//! Training knobs are `key=value` overrides on `config::RunConfig`
//! (dataset, model, parts, method, epochs, sync_interval, lr, optimizer,
//! overlap, eval_every, threads, seed, ...).  `threads=0` (default)
//! auto-sizes the worker pool to min(parts, cores); any thread count
//! produces bit-identical results.
//!
//! Session knobs (`coordinator::session` / `coordinator::hooks`):
//! `save_to=ck.json save_every=K` checkpoints the *full* training state
//! every K epochs (and at the end), `load_from=ck.json` resumes it
//! bit-exactly (raise `epochs` above the checkpoint's count to
//! continue), `stream_csv=live.csv` streams telemetry rows while
//! training runs, `early_stop=P` stops after P evaluations without
//! val-F1 improvement, and `wall_budget=SECS` bounds real time.  The
//! arg parser is hand-rolled: the offline crate cache has no clap (see
//! Cargo.toml note).

use digest::config::RunConfig;
use digest::exp::{run_experiment, Budget, Campaign};
use digest::graph::registry::{load, SPECS};
use digest::graph::stats::graph_stats;
use digest::partition::{partition, quality, PartitionAlgo};
use digest::util::human_bytes;
use digest::util::json::Json;
use digest::{coordinator, eyre, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "usage: digest <list|generate|partition|train|experiment> [args]\n\
     \n\
     digest list\n\
     digest generate --dataset <name> [--seed N]\n\
     digest partition --dataset <name> [--parts K] [--algo metis|bfs|random] [--seed N]\n\
     digest train [--config file.json] [--csv out.csv] [key=value ...]\n\
     \x20             (session knobs: save_to= save_every= load_from=\n\
     \x20              stream_csv= early_stop= wall_budget=)\n\
     digest experiment <id|all> [--out-dir results] [--quick] [--seed N]\n"
        .to_string()
}

/// Pull `--flag value` out of args; returns the value if present.
fn take_opt(args: &mut Vec<String>, flag: &str) -> Option<String> {
    if let Some(i) = args.iter().position(|a| a == flag) {
        if i + 1 < args.len() {
            let v = args.remove(i + 1);
            args.remove(i);
            return Some(v);
        }
        args.remove(i);
    }
    None
}

/// Pull a boolean `--flag` out of args.
fn take_flag(args: &mut Vec<String>, flag: &str) -> bool {
    if let Some(i) = args.iter().position(|a| a == flag) {
        args.remove(i);
        true
    } else {
        false
    }
}

fn run() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print!("{}", usage());
        return Ok(());
    }
    let cmd = args.remove(0);
    match cmd.as_str() {
        "list" => cmd_list(),
        "generate" => cmd_generate(args),
        "partition" => cmd_partition(args),
        "train" => cmd_train(args),
        "experiment" => cmd_experiment(args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(eyre!("unknown command {other:?}\n{}", usage())),
    }
}

fn cmd_list() -> Result<()> {
    println!("datasets:");
    for s in &SPECS {
        println!(
            "  {:12} (~{} nodes, {} classes, d={}, stands in for {}) -> artifact {}",
            s.name, s.nodes, s.n_class, s.d_in, s.paper_name, s.artifact
        );
    }
    match digest::runtime::Manifest::load("artifacts") {
        Ok(m) => {
            println!("\nartifacts ({}):", m.artifacts.len());
            let mut names: Vec<_> = m.artifacts.keys().collect();
            names.sort();
            for (name, kind) in names {
                println!("  {name} ({kind})");
            }
        }
        Err(_) => println!("\nartifacts: none built (run `make artifacts`)"),
    }
    println!("\nexperiments: {:?}", digest::exp::ALL_EXPERIMENTS);
    Ok(())
}

fn cmd_generate(mut args: Vec<String>) -> Result<()> {
    let dataset = take_opt(&mut args, "--dataset")
        .ok_or_else(|| eyre!("--dataset required"))?;
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let ds = load(&dataset, seed)?;
    ds.validate()?;
    let st = graph_stats(&ds.graph);
    println!("dataset {dataset} (seed {seed}):");
    println!("  nodes       {}", st.nodes);
    println!("  edges       {}", st.edges);
    println!("  avg degree  {:.2}", st.avg_degree);
    println!("  max degree  {}", st.max_degree);
    println!("  deg p50/p90/p99  {}/{}/{}", st.deg_p50, st.deg_p90, st.deg_p99);
    println!("  features    {} dims", ds.d_in());
    println!("  classes     {}", ds.n_class);
    let (tr, va, te) = (
        ds.nodes_in_split(digest::graph::Split::Train).len(),
        ds.nodes_in_split(digest::graph::Split::Val).len(),
        ds.nodes_in_split(digest::graph::Split::Test).len(),
    );
    println!("  split       {tr} train / {va} val / {te} test");
    Ok(())
}

fn cmd_partition(mut args: Vec<String>) -> Result<()> {
    let dataset = take_opt(&mut args, "--dataset")
        .ok_or_else(|| eyre!("--dataset required"))?;
    let parts: usize = take_opt(&mut args, "--parts").map_or(Ok(4), |s| {
        s.parse().map_err(|e| eyre!("--parts: {e}"))
    })?;
    let algo: PartitionAlgo = take_opt(&mut args, "--algo")
        .map_or(Ok(PartitionAlgo::Metis), |s| s.parse())?;
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let ds = load(&dataset, seed)?;
    let t0 = std::time::Instant::now();
    let p = partition(&ds.graph, parts, algo, seed);
    let elapsed = t0.elapsed();
    let q = quality::evaluate(&ds.graph, &p);
    println!("partitioned {dataset} into {parts} parts with {algo:?} in {elapsed:?}");
    println!("  sizes       {:?}", p.sizes());
    println!("  edge cut    {} ({:.2}% of edges)", q.edge_cut, 100.0 * q.cut_ratio);
    println!("  balance     {:.3}", q.balance);
    println!("  halo sizes  {:?}", q.halo_sizes);
    println!("  halo ratio  {:.1}%", 100.0 * q.avg_halo_ratio);
    Ok(())
}

fn cmd_train(mut args: Vec<String>) -> Result<()> {
    let mut cfg = match take_opt(&mut args, "--config") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| eyre!("reading {path}: {e}"))?;
            RunConfig::from_json(&Json::parse(&text)?)?
        }
        None => RunConfig::default(),
    };
    let csv_out = take_opt(&mut args, "--csv");
    // legacy flags; save_to= / load_from= overrides are the same knobs
    if let Some(path) = take_opt(&mut args, "--save") {
        cfg.save_to = Some(path);
    }
    if let Some(path) = take_opt(&mut args, "--load") {
        cfg.load_from = Some(path);
    }
    for kv in &args {
        cfg.apply_override(kv)?;
    }
    println!(
        "training {} / {} with {} on {} workers (N={}, epochs={}, lr={})",
        cfg.dataset,
        cfg.model.as_str(),
        cfg.method.as_str(),
        cfg.parts,
        cfg.sync_interval,
        cfg.epochs,
        cfg.lr
    );
    let mut ctx = coordinator::TrainContext::new(cfg)?;
    let loaded = coordinator::prepare_resume(&mut ctx)?;
    if let Some(ckpt) = &loaded {
        println!(
            "{} {} (epoch {}, best val F1 {:.4})",
            if ckpt.state.is_some() {
                "resuming training state from"
            } else {
                "warm-starting params from v1 checkpoint"
            },
            ctx.cfg.load_from.as_deref().unwrap_or("?"),
            ckpt.epoch,
            ckpt.best_val_f1
        );
    }
    let mut session = coordinator::session_from_checkpoint(&ctx, loaded.as_ref())?;
    let mut driver = coordinator::Driver::from_config(&ctx.cfg)?;
    let res = driver.run(session.as_mut())?;
    if let Some(reason) = driver.stop_reason() {
        println!("stopped early: {reason}");
    }
    if let Some(path) = &ctx.cfg.save_to {
        println!("training state saved to {path} (resume with load_from={path})");
    }
    println!("\nresults:");
    println!("  best val F1    {:.4}", res.best_val_f1);
    println!("  final val F1   {:.4}", res.final_val_f1);
    println!("  final test F1  {:.4}", res.final_test_f1);
    println!("  virtual time   {:.3}s ({:.4}s/epoch)", res.total_vtime, res.avg_epoch_vtime());
    println!("  wall time      {:.1}s ({} worker threads)", res.total_wall, res.threads);
    println!(
        "  KVS traffic    {} ({} pulls, {} pushes, {} misses)",
        human_bytes(res.kvs.total_bytes()),
        res.kvs.pulls,
        res.kvs.pushes,
        res.kvs.misses
    );
    if res.delay.updates > 0 && res.method == "digest-a" {
        println!(
            "  async delay    mean {:.2}, max {}",
            res.delay.mean_delay(),
            res.delay.max_delay
        );
    }
    if let Some(path) = csv_out {
        std::fs::write(&path, res.to_csv()).map_err(|e| eyre!("writing {path}: {e}"))?;
        println!("  timeline CSV   {path}");
    }
    Ok(())
}

fn cmd_experiment(mut args: Vec<String>) -> Result<()> {
    let out_dir = take_opt(&mut args, "--out-dir").unwrap_or_else(|| "results".into());
    let quick = take_flag(&mut args, "--quick");
    let seed: u64 = take_opt(&mut args, "--seed").map_or(Ok(42), |s| {
        s.parse().map_err(|e| eyre!("--seed: {e}"))
    })?;
    let id = args
        .first()
        .ok_or_else(|| eyre!("experiment id required (or 'all')"))?
        .clone();
    let budget = if quick { Budget::quick() } else { Budget::full() };
    let mut campaign = Campaign::new(&out_dir, budget, seed)?;
    let t0 = std::time::Instant::now();
    run_experiment(&id, &mut campaign)?;
    println!("experiment {id} done in {:?}; outputs in {out_dir}/", t0.elapsed());
    Ok(())
}
