//! Subgraph plans: halo extraction and padded propagation matrices.
//!
//! For each partition m this module materializes everything the AOT
//! train/eval artifacts need (paper Eq. 4/5):
//!
//! * `own`  — the in-subgraph nodes V_m (ascending global ids);
//! * `halo` — the out-of-subgraph neighbors ∪_{v∈V_m} N(v) \ V_m, ranked
//!   by connectivity to the subgraph and truncated to the artifact's
//!   `B_pad` budget (truncation counted — it is the only place DIGEST
//!   can lose information, and only when the artifact is under-sized);
//! * `p_in` (S_pad, S_pad) / `p_out` (S_pad, B_pad) — the full-graph GCN
//!   propagation matrix D̃^{-1/2}(A+I)D̃^{-1/2} split by column
//!   ownership (P = P_in + P_out restricted to V_m's rows), or binary
//!   attention masks for GAT (self-loops on the diagonal of every row,
//!   including padding, so no softmax row is empty);
//! * padded features `x`, labels `y`, and per-split masks.
//!
//! `p_in`/`p_out` are held as [`CsrMatrix`] and assembled in O(edges):
//! the old dense assembly allocated O(S_pad²) per plan, which is what
//! capped plan construction at toy scale.  They densify only at
//! literal-packing time ([`crate::runtime::pack_csr`]) — the packed
//! bytes are identical to the seed dense construction, so the AOT
//! artifact contract is unchanged.
//!
//! Zero padding is semantically inert by construction: the Python test
//! suite asserts padding invariance of the train step
//! (`test_train_step.py::test_padding_invariance`).

use crate::graph::{Dataset, Split};
use crate::partition::Partition;
use crate::tensor::sparse::{CsrBuilder, CsrMatrix};
use crate::tensor::Matrix;
use crate::{eyre, Result};

/// Which propagation encoding the model expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PropKind {
    /// GCN: symmetric-normalized weights with self-loops.
    GcnNormalized,
    /// GAT: binary adjacency masks, diag = 1 on all rows.
    GatMask,
}

/// Everything static about one subgraph's batch (representations and
/// weights are supplied per step by the coordinator).
#[derive(Debug, Clone)]
pub struct SubgraphPlan {
    pub part: usize,
    pub own: Vec<u32>,
    pub halo: Vec<u32>,
    /// Halo nodes dropped because the artifact's B_pad was too small.
    pub truncated_halo: usize,
    /// Cross edges dropped due to halo truncation.
    pub dropped_edges: usize,
    pub s_pad: usize,
    pub b_pad: usize,
    /// (s_pad, s_pad) in-subgraph propagation, sparse (see module doc).
    pub p_in: CsrMatrix,
    /// (s_pad, b_pad) halo propagation, sparse.
    pub p_out: CsrMatrix,
    /// (s_pad + b_pad, d_in): own rows then halo rows, zero padding.
    pub x: Matrix,
    /// (s_pad,) labels, 0 for padding.
    pub y: Vec<i32>,
    pub train_mask: Vec<f32>,
    pub val_mask: Vec<f32>,
    pub test_mask: Vec<f32>,
}

impl SubgraphPlan {
    pub fn n_own(&self) -> usize {
        self.own.len()
    }

    pub fn n_halo(&self) -> usize {
        self.halo.len()
    }

    /// Paper Fig. 9 metric for this subgraph.
    pub fn halo_ratio(&self) -> f64 {
        if self.own.is_empty() {
            0.0
        } else {
            (self.halo.len() + self.truncated_halo) as f64 / self.own.len() as f64
        }
    }

    pub fn mask(&self, split: Split) -> &[f32] {
        match split {
            Split::Train => &self.train_mask,
            Split::Val => &self.val_mask,
            Split::Test => &self.test_mask,
        }
    }

    /// FLOPs of one forward pass through an L-layer GNN on this plan
    /// (dense padded shapes — what the artifact actually executes).
    /// Used by the cost model.
    pub fn forward_flops(&self, dims: &[usize]) -> u64 {
        let s = self.s_pad as u64;
        let b = self.b_pad as u64;
        let mut flops = 0u64;
        for w in dims.windows(2) {
            let (din, dout) = (w[0] as u64, w[1] as u64);
            // transform [S+B, din] @ [din, dout] + aggregate [S, S+B] @ [S+B, dout]
            flops += 2 * (s + b) * din * dout + 2 * s * (s + b) * dout;
        }
        flops
    }
}

/// Build the subgraph plan for partition `m`.
pub fn build_plan(
    ds: &Dataset,
    p: &Partition,
    m: usize,
    s_pad: usize,
    b_pad: usize,
    kind: PropKind,
) -> Result<SubgraphPlan> {
    let g = &ds.graph;
    let own = p.members(m);
    if own.len() > s_pad {
        return Err(eyre!(
            "partition {m} has {} nodes > artifact S_pad {s_pad}",
            own.len()
        ));
    }

    // local index of own nodes
    let mut own_local = std::collections::HashMap::with_capacity(own.len());
    for (i, &v) in own.iter().enumerate() {
        own_local.insert(v, i);
    }

    // halo candidates with connection counts
    let mut conn: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for &v in &own {
        for &u in g.neighbors(v as usize) {
            if !own_local.contains_key(&u) {
                *conn.entry(u).or_insert(0) += 1;
            }
        }
    }
    let mut ranked: Vec<(u32, usize)> = conn.into_iter().collect();
    // heaviest-connected first; id tiebreak for determinism
    ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let keep = ranked.len().min(b_pad);
    let truncated_halo = ranked.len() - keep;
    let dropped_edges: usize = ranked[keep..].iter().map(|&(_, c)| c).sum();
    let mut halo: Vec<u32> = ranked[..keep].iter().map(|&(v, _)| v).collect();
    halo.sort_unstable(); // ascending ids for stable KVS addressing
    let mut halo_local = std::collections::HashMap::with_capacity(halo.len());
    for (i, &v) in halo.iter().enumerate() {
        halo_local.insert(v, i);
    }

    // propagation matrices: sparse row-by-row assembly, O(edges) — the
    // dense O(S_pad²) buffers only ever exist transiently at literal
    // packing (`runtime::pack_csr` scatters the same values into the
    // same slots, so the packed bytes match the seed construction)
    let mut p_in = CsrBuilder::new(s_pad, s_pad);
    let mut p_out = CsrBuilder::new(s_pad, b_pad);
    for (i, &v) in own.iter().enumerate() {
        match kind {
            PropKind::GcnNormalized => {
                // self-loop weight 1 / (d_v + 1)
                let dv = (g.degree(v as usize) + 1) as f32;
                p_in.push(i as u32, 1.0 / dv);
            }
            PropKind::GatMask => {
                p_in.push(i as u32, 1.0);
            }
        }
        for &u in g.neighbors(v as usize) {
            let w = match kind {
                PropKind::GcnNormalized => g.norm_weight(v as usize, u as usize),
                PropKind::GatMask => 1.0,
            };
            if let Some(&j) = own_local.get(&u) {
                p_in.push(j as u32, w);
            } else if let Some(&j) = halo_local.get(&u) {
                p_out.push(j as u32, w);
            }
            // else: truncated halo neighbor, edge dropped (counted above)
        }
        p_in.finish_row();
        p_out.finish_row();
    }
    if kind == PropKind::GatMask {
        // self-loops on padding rows keep every softmax row non-empty
        for i in own.len()..s_pad {
            p_in.push(i as u32, 1.0);
            p_in.finish_row();
        }
    }
    // unfinished rows (GCN padding) densify to all-zero rows
    let p_in = p_in.finish();
    let p_out = p_out.finish();

    // padded features
    let d = ds.d_in();
    let mut x = Matrix::zeros(s_pad + b_pad, d);
    for (i, &v) in own.iter().enumerate() {
        x.copy_row_from(i, ds.features.row(v as usize));
    }
    for (j, &v) in halo.iter().enumerate() {
        x.copy_row_from(s_pad + j, ds.features.row(v as usize));
    }

    // labels + split masks
    let mut y = vec![0i32; s_pad];
    let mut train_mask = vec![0f32; s_pad];
    let mut val_mask = vec![0f32; s_pad];
    let mut test_mask = vec![0f32; s_pad];
    for (i, &v) in own.iter().enumerate() {
        y[i] = ds.labels[v as usize] as i32;
        match ds.split[v as usize] {
            Split::Train => train_mask[i] = 1.0,
            Split::Val => val_mask[i] = 1.0,
            Split::Test => test_mask[i] = 1.0,
        }
    }

    Ok(SubgraphPlan {
        part: m,
        own,
        halo,
        truncated_halo,
        dropped_edges,
        s_pad,
        b_pad,
        p_in,
        p_out,
        x,
        y,
        train_mask,
        val_mask,
        test_mask,
    })
}

/// Build plans for every partition.
pub fn build_all_plans(
    ds: &Dataset,
    p: &Partition,
    s_pad: usize,
    b_pad: usize,
    kind: PropKind,
) -> Result<Vec<SubgraphPlan>> {
    (0..p.k)
        .map(|m| build_plan(ds, p, m, s_pad, b_pad, kind))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::registry::load;
    use crate::partition::{partition, PartitionAlgo};

    fn karate_plans(kind: PropKind) -> (Dataset, Vec<SubgraphPlan>) {
        let ds = load("karate", 0).unwrap();
        let p = partition(&ds.graph, 2, PartitionAlgo::Metis, 0);
        let plans = build_all_plans(&ds, &p, 32, 32, kind).unwrap();
        (ds, plans)
    }

    #[test]
    fn own_and_halo_disjoint_and_complete() {
        let (ds, plans) = karate_plans(PropKind::GcnNormalized);
        let mut all_own: Vec<u32> = plans.iter().flat_map(|p| p.own.clone()).collect();
        all_own.sort_unstable();
        assert_eq!(all_own, (0..ds.n() as u32).collect::<Vec<_>>());
        for plan in &plans {
            for h in &plan.halo {
                assert!(!plan.own.contains(h));
            }
        }
    }

    #[test]
    fn gcn_p_split_preserves_full_row_weight() {
        // P_in + P_out row sums must equal the full-graph P row sums (no
        // weight lost when B_pad is large enough).
        let (ds, plans) = karate_plans(PropKind::GcnNormalized);
        let g = &ds.graph;
        for plan in &plans {
            assert_eq!(plan.truncated_halo, 0);
            for (i, &v) in plan.own.iter().enumerate() {
                let vd = v as usize;
                let mut want = 1.0 / (g.degree(vd) + 1) as f32;
                for &u in g.neighbors(vd) {
                    want += g.norm_weight(vd, u as usize);
                }
                let got: f32 = plan.p_in.row_entries(i).1.iter().sum::<f32>()
                    + plan.p_out.row_entries(i).1.iter().sum::<f32>();
                assert!((got - want).abs() < 1e-5, "row {v}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn gat_masks_binary_with_full_diag() {
        let (_, plans) = karate_plans(PropKind::GatMask);
        for plan in &plans {
            for i in 0..plan.s_pad {
                assert_eq!(plan.p_in.get(i, i), 1.0, "diag row {i}");
            }
            // stored entries are exactly 1.0 (all other slots densify to 0)
            assert!(plan
                .p_in
                .values
                .iter()
                .chain(&plan.p_out.values)
                .all(|&v| v == 1.0));
        }
    }

    #[test]
    fn padding_rows_are_zero() {
        let (_, plans) = karate_plans(PropKind::GcnNormalized);
        for plan in &plans {
            let s_real = plan.n_own();
            for i in s_real..plan.s_pad {
                assert!(plan.p_in.row_entries(i).0.is_empty());
                assert!(plan.p_out.row_entries(i).0.is_empty());
                assert!(plan.x.row(i).iter().all(|&v| v == 0.0));
                assert_eq!(plan.train_mask[i], 0.0);
            }
            for j in plan.n_halo()..plan.b_pad {
                assert!(plan.x.row(plan.s_pad + j).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn features_copied_correctly() {
        let (ds, plans) = karate_plans(PropKind::GcnNormalized);
        for plan in &plans {
            for (i, &v) in plan.own.iter().enumerate() {
                assert_eq!(plan.x.row(i), ds.features.row(v as usize));
            }
            for (j, &v) in plan.halo.iter().enumerate() {
                assert_eq!(plan.x.row(plan.s_pad + j), ds.features.row(v as usize));
            }
        }
    }

    #[test]
    fn truncation_keeps_heaviest_connected() {
        let ds = load("karate", 0).unwrap();
        let p = partition(&ds.graph, 2, PartitionAlgo::Metis, 0);
        let full = build_plan(&ds, &p, 0, 32, 32, PropKind::GcnNormalized).unwrap();
        let tiny_b = 3usize;
        let trunc = build_plan(&ds, &p, 0, 32, tiny_b, PropKind::GcnNormalized).unwrap();
        assert_eq!(trunc.halo.len(), tiny_b);
        assert_eq!(trunc.truncated_halo, full.halo.len() - tiny_b);
        assert!(trunc.dropped_edges > 0);
        // kept halo nodes must each have >= connections than any dropped one
        let conn = |h: u32| -> usize {
            full.own
                .iter()
                .filter(|&&v| ds.graph.has_edge(v as usize, h as usize))
                .count()
        };
        let min_kept = trunc.halo.iter().map(|&h| conn(h)).min().unwrap();
        let dropped: Vec<u32> = full
            .halo
            .iter()
            .copied()
            .filter(|h| !trunc.halo.contains(h))
            .collect();
        let max_dropped = dropped.iter().map(|&h| conn(h)).max().unwrap();
        assert!(min_kept >= max_dropped);
    }

    #[test]
    fn oversized_partition_errors() {
        let ds = load("karate", 0).unwrap();
        let p = partition(&ds.graph, 1, PartitionAlgo::Metis, 0);
        assert!(build_plan(&ds, &p, 0, 16, 16, PropKind::GcnNormalized).is_err());
    }

    /// The seed's dense p_in/p_out assembly, kept verbatim: the sparse
    /// build must densify to *byte-identical* matrices (the AOT
    /// artifact contract — padded literals must not move).
    fn dense_reference(
        ds: &Dataset,
        plan: &SubgraphPlan,
        kind: PropKind,
    ) -> (Matrix, Matrix) {
        let g = &ds.graph;
        let mut p_in = Matrix::zeros(plan.s_pad, plan.s_pad);
        let mut p_out = Matrix::zeros(plan.s_pad, plan.b_pad);
        for (i, &v) in plan.own.iter().enumerate() {
            match kind {
                PropKind::GcnNormalized => {
                    let dv = (g.degree(v as usize) + 1) as f32;
                    p_in.set(i, i, 1.0 / dv);
                }
                PropKind::GatMask => p_in.set(i, i, 1.0),
            }
            for &u in g.neighbors(v as usize) {
                let w = match kind {
                    PropKind::GcnNormalized => g.norm_weight(v as usize, u as usize),
                    PropKind::GatMask => 1.0,
                };
                if let Ok(j) = plan.own.binary_search(&u) {
                    p_in.set(i, j, w);
                } else if let Ok(j) = plan.halo.binary_search(&u) {
                    p_out.set(i, j, w);
                }
            }
        }
        if kind == PropKind::GatMask {
            for i in plan.own.len()..plan.s_pad {
                p_in.set(i, i, 1.0);
            }
        }
        (p_in, p_out)
    }

    #[test]
    fn sparse_build_densifies_byte_identical_to_seed() {
        for kind in [PropKind::GcnNormalized, PropKind::GatMask] {
            let ds = load("karate", 0).unwrap();
            let p = partition(&ds.graph, 2, PartitionAlgo::Metis, 0);
            // include a truncating configuration (b_pad = 3)
            for b_pad in [32usize, 3] {
                for m in 0..2 {
                    let plan = build_plan(&ds, &p, m, 32, b_pad, kind).unwrap();
                    let (want_in, want_out) = dense_reference(&ds, &plan, kind);
                    let got_in = plan.p_in.to_dense();
                    let got_out = plan.p_out.to_dense();
                    let bits = |m: &Matrix| -> Vec<u32> {
                        m.data.iter().map(|v| v.to_bits()).collect()
                    };
                    assert_eq!(bits(&got_in), bits(&want_in), "{kind:?} b_pad={b_pad} p_in");
                    assert_eq!(bits(&got_out), bits(&want_out), "{kind:?} b_pad={b_pad} p_out");
                }
            }
        }
    }

    #[test]
    fn forward_flops_positive_and_monotone() {
        let (_, plans) = karate_plans(PropKind::GcnNormalized);
        let f2 = plans[0].forward_flops(&[16, 16, 4]);
        let f3 = plans[0].forward_flops(&[16, 16, 16, 4]);
        assert!(f2 > 0);
        assert!(f3 > f2);
    }

    #[test]
    fn prop_halo_invariants_random_graphs() {
        use crate::graph::generators::{generate_sbm, SbmParams};
        crate::util::prop::prop_check(15, |rng| {
            let n = 40 + rng.below(80);
            let k = 2 + rng.below(3);
            let ds = generate_sbm(&SbmParams {
                name: "prop".into(),
                nodes: n,
                communities: 4,
                intra_degree: 6.0,
                inter_degree: 2.0,
                d_in: 8,
                signal: 1.0,
                skew: 0.0,
                label_noise: 0.0,
                train_frac: 0.5,
                val_frac: 0.25,
                seed: rng.next_u64(),
            });
            let p = partition(&ds.graph, k, PartitionAlgo::Metis, rng.next_u64());
            let s_pad = ds.n(); // generous
            let plans = build_all_plans(&ds, &p, s_pad, s_pad, PropKind::GcnNormalized)
                .map_err(|e| e.to_string())?;
            // every cross edge appears in exactly one p_out entry per side
            for plan in &plans {
                crate::prop_assert!(plan.truncated_halo == 0, "no truncation expected");
                for (i, &v) in plan.own.iter().enumerate() {
                    for &u in ds.graph.neighbors(v as usize) {
                        let in_own = plan.own.binary_search(&u).is_ok();
                        let hj = plan.halo.binary_search(&u);
                        crate::prop_assert!(
                            in_own != hj.is_ok(),
                            "neighbor {u} must be own XOR halo"
                        );
                        if let Ok(j) = hj {
                            crate::prop_assert!(
                                plan.p_out.get(i, j) > 0.0,
                                "cross edge ({v},{u}) missing from p_out"
                            );
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
