//! Seeded, deterministic, partition-aware neighbor sampling.
//!
//! [`BlockSampler::sample_batch`] turns a batch of seed nodes into one
//! bipartite [`Block`] per GNN layer: layer l's block aggregates the
//! (sampled) layer-l inputs of the nodes the layer above needs.  Blocks
//! are built top-down — seeds first, then each deeper source set — and
//! every destination set is a **prefix of its source set**, which is
//! what lets the forward reuse one hidden matrix per layer and the
//! backward address destination rows without an index map.
//!
//! Determinism: all sampling is driven by the caller's [`Rng`], the
//! node sets are built in first-visit order, and sampled neighbor lists
//! are sorted ascending before they enter the CSR.  One worker's batch
//! stream is therefore a pure function of its seed — the engine can run
//! any number of workers on any number of threads and every worker
//! still draws exactly the sequence it would have drawn alone.
//!
//! Steady state allocates nothing: the dedup marks, the per-layer CSRs
//! and the neighbor scratch all persist across batches and are cleared,
//! not dropped.  [`SamplerStats::grows`] counts capacity growth so
//! tests can assert the zero-alloc steady state.

use crate::graph::Graph;
use crate::util::Rng;

/// One sampled bipartite block: `n_dst` destination nodes (a prefix of
/// `src`) each aggregate over their sampled-neighbor rows.
#[derive(Debug, Default, Clone)]
pub struct Block {
    /// Global node ids of the source set; the first `n_dst` entries are
    /// the destination nodes, in the order the layer above emitted them.
    pub src: Vec<u32>,
    pub n_dst: usize,
    /// CSR offsets over destination rows (`row_ptr.len() == n_dst + 1`).
    pub row_ptr: Vec<usize>,
    /// Column indices into `src`, ascending within each row.
    pub cols: Vec<u32>,
    /// Mean weights: `1 / sampled_degree` (an unbiased estimate of the
    /// full neighbor mean; exact when the fanout covers the degree).
    pub vals: Vec<f32>,
}

impl Block {
    pub fn n_src(&self) -> usize {
        self.src.len()
    }

    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    fn clear(&mut self) {
        self.src.clear();
        self.n_dst = 0;
        self.row_ptr.clear();
        self.cols.clear();
        self.vals.clear();
    }

    fn capacity(&self) -> usize {
        self.src.capacity() + self.row_ptr.capacity() + self.cols.capacity() + self.vals.capacity()
    }
}

/// Capacity-growth counters ([`BlockSampler`] steady state must hold
/// `grows` constant while `batches` keeps climbing).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SamplerStats {
    /// Batches sampled through this sampler.
    pub batches: u64,
    /// Batches after which some internal buffer had grown past its
    /// previous high-water capacity.
    pub grows: u64,
}

/// Reusable multi-layer neighbor sampler (one per worker / per serving
/// scratch slot; not shared across threads).
pub struct BlockSampler {
    /// Round-stamped dedup marks, one per graph node.
    mark: Vec<u32>,
    /// Position of a marked node in the block being built.
    pos: Vec<u32>,
    round: u32,
    /// Neighbor scratch for the local-first split and the sample draw.
    local_buf: Vec<u32>,
    remote_buf: Vec<u32>,
    pick_buf: Vec<u32>,
    /// One block per GNN layer; `blocks[0]` is the input-side block.
    pub blocks: Vec<Block>,
    pub stats: SamplerStats,
    cap_high: usize,
}

impl BlockSampler {
    pub fn new(n: usize) -> Self {
        BlockSampler {
            mark: vec![0; n],
            pos: vec![0; n],
            round: 0,
            local_buf: Vec::new(),
            remote_buf: Vec::new(),
            pick_buf: Vec::new(),
            blocks: Vec::new(),
            stats: SamplerStats::default(),
            cap_high: 0,
        }
    }

    /// Sample the blocks for one batch of `seeds` (global node ids;
    /// duplicates collapse).  `fanouts[l]` bounds the sampled degree of
    /// layer l's block.  When `home` is set to a partition id, sampling
    /// is partition-aware: neighbors inside `home` are drawn first and
    /// remote ones only fill the remainder, shrinking cross-partition
    /// feature traffic without biasing the within-budget estimate.
    /// Draws come from `rng` only for nodes whose degree exceeds the
    /// fanout, so a covering fanout consumes no randomness at all.
    pub fn sample_batch(
        &mut self,
        g: &Graph,
        fanouts: &[usize],
        seeds: &[u32],
        home: Option<(&[u32], u32)>,
        rng: &mut Rng,
    ) {
        let layers = fanouts.len();
        if self.blocks.len() != layers {
            self.blocks.resize_with(layers, Block::default);
        }
        // top-down: block l+1's source set is block l's destination set
        for l in (0..layers).rev() {
            self.next_round();
            let round = self.round;
            let (head, tail) = self.blocks.split_at_mut(l + 1);
            let b = &mut head[l];
            b.clear();
            // seed the source set with the destination nodes (dedups
            // duplicate seeds on the outermost layer)
            if l + 1 == layers {
                for &v in seeds {
                    mark_push(&mut self.mark, &mut self.pos, round, &mut b.src, v);
                }
            } else {
                for &v in &tail[0].src {
                    mark_push(&mut self.mark, &mut self.pos, round, &mut b.src, v);
                }
            }
            b.n_dst = b.src.len();
            b.row_ptr.push(0);
            let k = fanouts[l];
            for i in 0..b.n_dst {
                let v = b.src[i];
                let nbrs = g.neighbors(v as usize);
                self.pick_buf.clear();
                if nbrs.len() <= k {
                    // covering fanout: exact neighbor mean, no draws
                    self.pick_buf.extend_from_slice(nbrs);
                } else {
                    match home {
                        Some((parts, my)) => {
                            self.local_buf.clear();
                            self.remote_buf.clear();
                            for &u in nbrs {
                                if parts[u as usize] == my {
                                    self.local_buf.push(u);
                                } else {
                                    self.remote_buf.push(u);
                                }
                            }
                            if self.local_buf.len() >= k {
                                sample_into(&mut self.local_buf, k, rng, &mut self.pick_buf);
                            } else {
                                self.pick_buf.extend_from_slice(&self.local_buf);
                                let need = k - self.local_buf.len();
                                sample_into(&mut self.remote_buf, need, rng, &mut self.pick_buf);
                            }
                        }
                        None => {
                            self.local_buf.clear();
                            self.local_buf.extend_from_slice(nbrs);
                            sample_into(&mut self.local_buf, k, rng, &mut self.pick_buf);
                        }
                    }
                    // canonical ascending order: the CSR (and therefore
                    // the forward's accumulation order) is independent
                    // of how the draw permuted the picks
                    self.pick_buf.sort_unstable();
                }
                if !self.pick_buf.is_empty() {
                    let inv = 1.0 / self.pick_buf.len() as f32;
                    for &u in &self.pick_buf {
                        mark_push(&mut self.mark, &mut self.pos, round, &mut b.src, u);
                        b.cols.push(self.pos[u as usize]);
                        b.vals.push(inv);
                    }
                }
                b.row_ptr.push(b.cols.len());
            }
        }
        self.stats.batches += 1;
        let cap = self.blocks.iter().map(Block::capacity).sum::<usize>()
            + self.local_buf.capacity()
            + self.remote_buf.capacity()
            + self.pick_buf.capacity();
        if cap > self.cap_high {
            self.cap_high = cap;
            self.stats.grows += 1;
        }
    }

    fn next_round(&mut self) {
        if self.round == u32::MAX {
            self.mark.fill(0);
            self.round = 0;
        }
        self.round += 1;
    }
}

/// Mark `v` as a member of the block being built and append it to the
/// source set if this is its first visit this round.
#[inline]
fn mark_push(mark: &mut [u32], pos: &mut [u32], round: u32, src: &mut Vec<u32>, v: u32) {
    let vi = v as usize;
    if mark[vi] != round {
        mark[vi] = round;
        pos[vi] = src.len() as u32;
        src.push(v);
    }
}

/// Append `k` elements drawn without replacement from `buf` (partial
/// Fisher-Yates; `buf` is scratch and gets permuted).
fn sample_into(buf: &mut [u32], k: usize, rng: &mut Rng, out: &mut Vec<u32>) {
    let k = k.min(buf.len());
    for i in 0..k {
        let j = i + rng.below(buf.len() - i);
        buf.swap(i, j);
        out.push(buf[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::registry::load;
    use crate::partition::{partition, PartitionAlgo};

    fn blocks_fingerprint(s: &BlockSampler) -> Vec<u64> {
        let mut out = Vec::new();
        for b in &s.blocks {
            let mut h = crate::util::Fnv64::new();
            for &v in &b.src {
                h.mix(v as u64);
            }
            h.mix(b.n_dst as u64);
            for &c in &b.cols {
                h.mix(c as u64);
            }
            for &w in &b.row_ptr {
                h.mix(w as u64);
            }
            for &x in &b.vals {
                h.mix_f32(x);
            }
            out.push(h.finish());
        }
        out
    }

    #[test]
    fn blocks_are_deterministic_and_steady_state_alloc_free() {
        let ds = load("arxiv-s", 0).unwrap();
        let mut s1 = BlockSampler::new(ds.n());
        let mut s2 = BlockSampler::new(ds.n());
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let seeds: Vec<u32> = (0..32u32).collect();
        for _ in 0..3 {
            s1.sample_batch(&ds.graph, &[5, 10], &seeds, None, &mut r1);
            s2.sample_batch(&ds.graph, &[5, 10], &seeds, None, &mut r2);
            assert_eq!(blocks_fingerprint(&s1), blocks_fingerprint(&s2));
        }
        // identical batches (re-seeded rng): the capacity high-water
        // stops moving after the first, so steady state allocates
        // nothing — a stochastic stream only ratchets it amortizedly
        s1.sample_batch(&ds.graph, &[5, 10], &seeds, None, &mut Rng::new(9));
        let warm = s1.stats.grows;
        for _ in 0..10 {
            s1.sample_batch(&ds.graph, &[5, 10], &seeds, None, &mut Rng::new(9));
        }
        assert_eq!(s1.stats.grows, warm, "steady-state batch grew a buffer");
        assert_eq!(s1.stats.batches, 14);
    }

    #[test]
    fn block_structure_invariants_hold() {
        let ds = load("karate", 0).unwrap();
        let mut s = BlockSampler::new(ds.n());
        let mut rng = Rng::new(7);
        let seeds = [0u32, 5, 9, 5]; // duplicate seed collapses
        s.sample_batch(&ds.graph, &[2, 3], &seeds, None, &mut rng);
        assert_eq!(s.blocks.len(), 2);
        let top = &s.blocks[1];
        assert_eq!(top.n_dst, 3);
        assert_eq!(&top.src[..3], &[0, 5, 9]);
        // deeper block's destination set is the top block's source set
        let bot = &s.blocks[0];
        assert_eq!(bot.n_dst, top.n_src());
        assert_eq!(&bot.src[..bot.n_dst], &top.src[..]);
        for b in &s.blocks {
            assert_eq!(b.row_ptr.len(), b.n_dst + 1);
            assert_eq!(*b.row_ptr.last().unwrap(), b.nnz());
            for i in 0..b.n_dst {
                let row = &b.cols[b.row_ptr[i]..b.row_ptr[i + 1]];
                assert!(row.windows(2).all(|w| w[0] < w[1]), "row not ascending");
                let deg = ds.graph.degree(b.src[i] as usize);
                assert!(row.len() <= deg);
            }
            assert!(b.src.iter().all(|&v| (v as usize) < ds.n()));
        }
    }

    #[test]
    fn covering_fanout_takes_all_neighbors_exactly() {
        let ds = load("karate", 0).unwrap();
        let max_deg = ds.graph.max_degree();
        let mut s = BlockSampler::new(ds.n());
        let mut rng = Rng::new(3);
        let before = rng.state();
        s.sample_batch(&ds.graph, &[max_deg], &[0, 1], None, &mut rng);
        // covering fanout draws nothing from the rng
        assert_eq!(rng.state(), before);
        let b = &s.blocks[0];
        for i in 0..b.n_dst {
            let v = b.src[i] as usize;
            let row = &b.cols[b.row_ptr[i]..b.row_ptr[i + 1]];
            let got: Vec<u32> = row.iter().map(|&c| b.src[c as usize]).collect();
            assert_eq!(got, ds.graph.neighbors(v), "node {v} row != neighbors");
            let (lo, hi) = (b.row_ptr[i], b.row_ptr[i + 1]);
            for &x in &b.vals[lo..hi] {
                assert_eq!(x, 1.0 / got.len() as f32);
            }
        }
    }

    #[test]
    fn partition_aware_sampling_prefers_local_neighbors() {
        let ds = load("arxiv-s", 0).unwrap();
        let part = partition(&ds.graph, 4, PartitionAlgo::Metis, 0);
        let seeds: Vec<u32> = part.members(0).into_iter().take(64).collect();
        let count_remote = |s: &BlockSampler| -> usize {
            let b = &s.blocks[0];
            b.cols
                .iter()
                .filter(|&&c| part.parts[b.src[c as usize] as usize] != 0)
                .count()
        };
        let mut aware = BlockSampler::new(ds.n());
        let mut blind = BlockSampler::new(ds.n());
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        aware.sample_batch(&ds.graph, &[4], &seeds, Some((&part.parts, 0)), &mut r1);
        blind.sample_batch(&ds.graph, &[4], &seeds, None, &mut r2);
        assert!(
            count_remote(&aware) <= count_remote(&blind),
            "partition-aware sampling drew more remote neighbors ({} > {})",
            count_remote(&aware),
            count_remote(&blind)
        );
    }
}
