//! Mini-batch neighbor-sampled GraphSAGE training (`method=sampled`).
//!
//! The full-graph method family (DIGEST, LLCG, DGL) trains every epoch
//! over all nodes of every partition, exchanging *stale hidden
//! representations* through the KVS.  This module is the third family
//! from the paper's experimental baseline set: **neighbor sampling**
//! (GraphSAGE, Hamilton et al. 2017).  Each step trains on a mini-batch
//! of seed nodes and a sampled multi-layer block around them, so the
//! per-step cost is bounded by the fanout product instead of the graph
//! size — and nothing stale is ever consumed: sampled training reads
//! exact layer-0 features only.
//!
//! The pieces:
//!
//! * [`sampler::BlockSampler`] — seeded, deterministic neighbor
//!   sampling that materializes per-layer block CSRs into reused
//!   scratch (zero allocation in steady state).  Sampling is
//!   **partition-aware**: local neighbors are preferred, so remote
//!   feature traffic shrinks before the cache even sees it.
//! * [`cache::FeatureCache`] — a frequency-tracked cache of *remote*
//!   feature rows, filled through [`crate::kvs::RepStore::pull_into`].
//!   Hits, misses and pulled bytes are first-class telemetry
//!   ([`crate::coordinator::telemetry::LogPoint`] `cache_*` columns).
//! * [`forward::BlockForward`] — the pure-Rust SAGE forward/backward
//!   over sampled blocks, sharing the summation-order contract with
//!   [`crate::gnn::Workspace`] so full-fanout sampled logits are
//!   bit-identical to the full-graph forward.
//! * [`session::SampledSession`] — a
//!   [`crate::coordinator::session::TrainSession`] over the existing
//!   parameter-server and virtual-clock machinery, with v2-checkpoint
//!   bit-exact resume.
//!
//! SAGE has no ahead-of-time compiled artifacts; [`sage_artifact_spec`]
//! synthesizes the [`ArtifactSpec`] the rest of the stack (parameter
//! init, cost model, checkpoints) keys off, from the config and
//! dataset dims alone.

pub mod cache;
pub mod forward;
pub mod sampler;
pub mod session;

pub use cache::FeatureCache;
pub use forward::BlockForward;
pub use sampler::{Block, BlockSampler, SamplerStats};
pub use session::{run_sampled, SampledSession};

use crate::config::RunConfig;
use crate::graph::Dataset;
use crate::partition::Partition;
use crate::runtime::{ArtifactSpec, DType, TensorSpec};
use crate::{eyre, Result};

/// Round up to the next multiple of 8 (the padding rule the AOT
/// artifacts use; kept for shape parity even though the sampled path
/// never pads its blocks).
pub fn pad8(n: usize) -> usize {
    n.div_ceil(8).max(1) * 8
}

/// Synthesize the [`ArtifactSpec`] for a SAGE model from the run config
/// and dataset dims.
///
/// The sampled path executes no AOT artifact — training and serving are
/// pure Rust — but the whole coordinator stack keys off a spec: layer
/// dims for parameter init ([`crate::runtime::init_params`] matches on
/// the `_w`/`_b` name suffixes), `param_bytes` for the PS cost model,
/// `s_pad`/`b_pad` for the halo plans the cost model still prices.
/// The input list follows the exact artifact contract (`x`, `p_in`,
/// `p_out`, stale tensors, per-layer params, `y`, `mask`) so every
/// shape-derived quantity behaves as if a manifest entry existed.
///
/// Per-layer parameter layout (matches [`crate::gnn::layer_views`] for
/// [`crate::gnn::ModelKind::Sage`]): `l{i}_w` (self transform),
/// `l{i}_b` (bias), `l{i}_nb_w` (neighbor-aggregate transform).
pub fn sage_artifact_spec(
    cfg: &RunConfig,
    ds: &Dataset,
    part: &Partition,
    kind: &str,
) -> Result<ArtifactSpec> {
    if kind != "train" && kind != "eval" {
        return Err(eyre!("artifact kind must be train|eval, got {kind:?}"));
    }
    let layers = cfg.hidden.len() + 1;
    let d_in = ds.features.cols;
    let n_class = ds.n_class;
    // single-layer models have no hidden width; dims() never reads d_h
    // then, but keep it meaningful
    let d_h = cfg.hidden.first().copied().unwrap_or(n_class);
    let max_part = part.sizes().into_iter().max().unwrap_or(1);
    let s_pad = pad8(max_part);
    let b_pad = pad8(ds.n());

    // layer widths [d_in, d_h, .., n_class]
    let mut dims = vec![d_in];
    dims.extend(std::iter::repeat(d_h).take(layers - 1));
    dims.push(n_class);

    let f32t = |name: String, shape: Vec<usize>| TensorSpec {
        name,
        shape,
        dtype: DType::F32,
    };
    let mut inputs = vec![
        f32t("x".into(), vec![s_pad + b_pad, d_in]),
        f32t("p_in".into(), vec![s_pad, s_pad]),
        f32t("p_out".into(), vec![s_pad, b_pad]),
    ];
    for i in 1..layers {
        inputs.push(f32t(format!("h_stale_{i}"), vec![b_pad, d_h]));
    }
    for i in 0..layers {
        inputs.push(f32t(format!("l{i}_w"), vec![dims[i], dims[i + 1]]));
        inputs.push(f32t(format!("l{i}_b"), vec![dims[i + 1]]));
        inputs.push(f32t(format!("l{i}_nb_w"), vec![dims[i], dims[i + 1]]));
    }
    inputs.push(TensorSpec {
        name: "y".into(),
        shape: vec![s_pad],
        dtype: DType::I32,
    });
    inputs.push(f32t("mask".into(), vec![s_pad]));

    let outputs = if kind == "train" {
        vec![
            f32t("loss".into(), vec![1]),
            f32t("ncorrect".into(), vec![1]),
            f32t("logits".into(), vec![s_pad, n_class]),
        ]
    } else {
        vec![f32t("logits".into(), vec![s_pad, n_class])]
    };

    Ok(ArtifactSpec {
        name: cfg.artifact_name()?,
        kind: kind.to_string(),
        model: "sage".to_string(),
        // never loaded: the sampled path has no HLO executable
        file: String::new(),
        layers,
        s_pad,
        b_pad,
        d_in,
        d_h,
        n_class,
        act: "relu".to_string(),
        normalize: false,
        inputs,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, RunConfig};
    use crate::gnn::ModelKind;
    use crate::graph::registry::load;
    use crate::partition::{partition, PartitionAlgo};

    fn sage_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.method = Method::Sampled;
        cfg.model = ModelKind::Sage;
        cfg
    }

    #[test]
    fn synthesized_spec_matches_artifact_contract() {
        let cfg = sage_cfg();
        let ds = load("karate", cfg.seed).unwrap();
        let part = partition(&ds.graph, 2, PartitionAlgo::Bfs, cfg.seed);
        let spec = sage_artifact_spec(&cfg, &ds, &part, "train").unwrap();
        assert_eq!(spec.layers, 2);
        assert_eq!(spec.dims(), vec![16, 16, 4]);
        assert_eq!(spec.n_params(), 6);
        // offset walks past x, p_in, p_out, and L-1 stale tensors
        let off = spec.param_input_offset();
        assert_eq!(spec.inputs[off].name, "l0_w");
        assert_eq!(spec.inputs[off + 1].name, "l0_b");
        assert_eq!(spec.inputs[off + 2].name, "l0_nb_w");
        assert_eq!(spec.inputs[off + 3].name, "l1_w");
        // init_params understands the names and shapes
        let params = crate::runtime::init_params(&spec, 7);
        assert_eq!(params.len(), 6);
        assert_eq!((params[0].rows, params[0].cols), (16, 16));
        assert_eq!((params[1].rows, params[1].cols), (1, 16));
        assert_eq!((params[2].rows, params[2].cols), (16, 16));
        assert_eq!((params[3].rows, params[3].cols), (16, 4));
        // eval spec carries only logits
        let eval = sage_artifact_spec(&cfg, &ds, &part, "eval").unwrap();
        assert_eq!(eval.outputs.len(), 1);
        assert!(sage_artifact_spec(&cfg, &ds, &part, "serve").is_err());
    }

    #[test]
    fn single_layer_spec_has_no_stale_tensors() {
        let mut cfg = sage_cfg();
        cfg.hidden = vec![];
        cfg.fanouts = vec![10];
        let ds = load("karate", cfg.seed).unwrap();
        let part = partition(&ds.graph, 2, PartitionAlgo::Bfs, cfg.seed);
        let spec = sage_artifact_spec(&cfg, &ds, &part, "train").unwrap();
        assert_eq!(spec.layers, 1);
        assert_eq!(spec.dims(), vec![16, 4]);
        assert_eq!(spec.param_input_offset(), 3);
        assert_eq!(spec.inputs[3].name, "l0_w");
        assert_eq!(spec.n_params(), 3);
    }
}
