//! Frequency-tracked cache of remote layer-0 feature rows.
//!
//! During sampled training every worker owns its partition's feature
//! rows outright (read straight from the dataset) but must fetch rows
//! for cross-partition neighbors through the representation plane
//! ([`crate::kvs::RepStore::pull_into`]).  This cache sits in front of
//! those pulls: hot remote rows are kept locally, and admission is
//! frequency-gated (LFU with lowest-slot tie-break) so one cold scan
//! cannot evict the working set.
//!
//! Feature rows are **immutable** for the lifetime of a run, so a hit
//! is always exact — the cache changes *traffic*, never *math*.  The
//! hit/miss/byte counters feed the `cache_*` telemetry columns, and
//! the slot table serializes into the checkpoint so a resumed run
//! replays the same hit sequence an uninterrupted run would have seen.

use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::{eyre, Result};

/// Sentinel for "node is not cached" in the slot map.
const NO_SLOT: u32 = u32::MAX;

/// LFU cache of remote feature rows (one per worker; single-threaded).
pub struct FeatureCache {
    /// Max rows cached; 0 disables the cache entirely.
    cap: usize,
    /// Row width (d_in).
    d: usize,
    /// node id -> occupied slot, or [`NO_SLOT`].
    slot_of: Vec<u32>,
    /// slot -> node id, in slot order (`len()` = filled slots).
    slot_node: Vec<u32>,
    /// Flat row storage, `cap * d` once the first row lands.
    rows: Vec<f32>,
    /// Access frequency per node (hits and misses both count: a miss
    /// is still evidence the row is wanted).
    freq: Vec<u32>,
    pub hits: u64,
    pub misses: u64,
    /// Bytes pulled through the representation plane on misses.
    pub bytes: u64,
}

impl FeatureCache {
    pub fn new(n: usize, d: usize, cap: usize) -> Self {
        FeatureCache {
            cap,
            d,
            slot_of: vec![NO_SLOT; n],
            slot_node: Vec::new(),
            rows: Vec::new(),
            freq: vec![0; n],
            hits: 0,
            misses: 0,
            bytes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.slot_node.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slot_node.is_empty()
    }

    /// Record an access to node `u` and copy its row into `out` on a
    /// hit.  Returns `true` on hit; on a miss the caller pulls the row
    /// remotely and offers it back via [`FeatureCache::admit`].
    pub fn lookup(&mut self, u: u32, out: &mut [f32]) -> bool {
        let ui = u as usize;
        self.freq[ui] = self.freq[ui].saturating_add(1);
        let slot = self.slot_of[ui];
        if slot == NO_SLOT {
            self.misses += 1;
            return false;
        }
        self.hits += 1;
        let s = slot as usize;
        out.copy_from_slice(&self.rows[s * self.d..(s + 1) * self.d]);
        true
    }

    /// Offer a freshly pulled row for caching.  Admission is
    /// frequency-gated: a free slot always takes the row; a full cache
    /// evicts its least-frequent resident (lowest slot on ties) only if
    /// the newcomer is strictly more frequent.
    pub fn admit(&mut self, u: u32, row: &[f32]) {
        if self.cap == 0 || self.slot_of[u as usize] != NO_SLOT {
            return;
        }
        debug_assert_eq!(row.len(), self.d);
        if self.slot_node.len() < self.cap {
            let slot = self.slot_node.len();
            self.slot_node.push(u);
            self.slot_of[u as usize] = slot as u32;
            self.rows.extend_from_slice(row);
            return;
        }
        let mut victim = 0usize;
        for (s, &node) in self.slot_node.iter().enumerate() {
            if self.freq[node as usize] < self.freq[self.slot_node[victim] as usize] {
                victim = s;
            }
        }
        let old = self.slot_node[victim];
        if self.freq[u as usize] <= self.freq[old as usize] {
            return;
        }
        self.slot_of[old as usize] = NO_SLOT;
        self.slot_of[u as usize] = victim as u32;
        self.slot_node[victim] = u;
        self.rows[victim * self.d..(victim + 1) * self.d].copy_from_slice(row);
    }

    /// Checkpoint form: slot table in slot order plus the sparse
    /// frequency table and the traffic counters.  Row *contents* are
    /// deliberately not serialized — features are immutable, so resume
    /// re-materializes them from the dataset without touching the
    /// representation plane (and without perturbing its metrics).
    pub fn export_json(&self) -> Json {
        let freq: Vec<Json> = self
            .freq
            .iter()
            .enumerate()
            .filter(|&(_, &f)| f > 0)
            .map(|(v, &f)| Json::Arr(vec![Json::uint(v as u64), Json::uint(f as u64)]))
            .collect();
        Json::obj(vec![
            (
                "slots",
                Json::Arr(self.slot_node.iter().map(|&v| Json::uint(v as u64)).collect()),
            ),
            ("freq", Json::Arr(freq)),
            ("hits", Json::uint(self.hits)),
            ("misses", Json::uint(self.misses)),
            ("bytes", Json::uint(self.bytes)),
        ])
    }

    /// Restore from [`FeatureCache::export_json`], re-materializing row
    /// contents from `features` (the immutable source of truth).
    pub fn import_json(&mut self, j: &Json, features: &Matrix) -> Result<()> {
        self.slot_node.clear();
        self.rows.clear();
        self.slot_of.fill(NO_SLOT);
        self.freq.fill(0);
        for e in j.get("freq")?.as_arr()? {
            let pair = e.as_arr()?;
            if pair.len() != 2 {
                return Err(eyre!("cache freq entry is not a [node, count] pair"));
            }
            let v = pair[0].as_usize()?;
            if v >= self.freq.len() {
                return Err(eyre!("cache freq node {v} out of range"));
            }
            self.freq[v] = pair[1].as_u64()? as u32;
        }
        for s in j.get("slots")?.as_arr()? {
            let v = s.as_usize()?;
            if v >= self.slot_of.len() {
                return Err(eyre!("cached node {v} out of range"));
            }
            if self.slot_node.len() >= self.cap {
                return Err(eyre!(
                    "checkpoint caches {} rows but cache_nodes is {}",
                    self.slot_node.len() + 1,
                    self.cap
                ));
            }
            self.slot_of[v] = self.slot_node.len() as u32;
            self.slot_node.push(v as u32);
            self.rows.extend_from_slice(features.row(v));
        }
        self.hits = j.get("hits")?.as_u64()?;
        self.misses = j.get("misses")?.as_u64()?;
        self.bytes = j.get("bytes")?.as_u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f32, d: usize) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn lfu_admission_and_eviction() {
        let d = 4;
        let mut c = FeatureCache::new(10, d, 2);
        let mut out = vec![0.0; d];
        // two misses fill the cache
        assert!(!c.lookup(1, &mut out));
        c.admit(1, &row(1.0, d));
        assert!(!c.lookup(2, &mut out));
        c.admit(2, &row(2.0, d));
        assert!(c.lookup(1, &mut out));
        assert_eq!(out, row(1.0, d));
        // node 3 (freq 1) cannot evict node 2 (freq 1): not strictly hotter
        assert!(!c.lookup(3, &mut out));
        c.admit(3, &row(3.0, d));
        assert!(!c.lookup(3, &mut out));
        // ...but after enough misses it out-ranks node 2 (freq 1 < 3)
        assert!(!c.lookup(3, &mut out));
        c.admit(3, &row(3.0, d));
        assert!(c.lookup(3, &mut out));
        assert_eq!(out, row(3.0, d));
        // node 1 (freq 2 + this lookup) survived; node 2 was the victim
        assert!(c.lookup(1, &mut out));
        assert!(!c.lookup(2, &mut out));
        assert_eq!(c.hits, 3);
        assert_eq!(c.misses, 6);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let d = 2;
        let mut c = FeatureCache::new(4, d, 0);
        let mut out = vec![0.0; d];
        for _ in 0..3 {
            assert!(!c.lookup(0, &mut out));
            c.admit(0, &row(9.0, d));
        }
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 3);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn json_round_trip_restores_slots_freq_and_counters() {
        let d = 3;
        let features = Matrix::from_fn(6, d, |r, c| (r * d + c) as f32);
        let mut c = FeatureCache::new(6, d, 3);
        let mut out = vec![0.0; d];
        for u in [4u32, 2, 4, 5] {
            if !c.lookup(u, &mut out) {
                c.admit(u, features.row(u as usize));
            }
        }
        c.bytes = 36;
        let j = c.export_json();
        let mut c2 = FeatureCache::new(6, d, 3);
        c2.import_json(&j, &features).unwrap();
        assert_eq!(c2.len(), 3);
        assert_eq!((c2.hits, c2.misses, c2.bytes), (c.hits, c.misses, c.bytes));
        // restored rows serve hits with the exact feature bits
        assert!(c2.lookup(4, &mut out));
        assert_eq!(out, features.row(4));
        // slot order survived (slot 0 is still node 4)
        assert_eq!(c2.export_json().get("slots").unwrap().as_arr().unwrap()[0]
            .as_usize()
            .unwrap(), 4);
    }
}
