//! Pure-Rust SAGE forward/backward over sampled blocks.
//!
//! [`BlockForward`] owns every per-layer matrix a mini-batch step
//! needs; buffers are resized in place (capacity is kept), so a warmed
//! worker allocates nothing per batch.  All kernels are sequential —
//! one worker's math never fans out — and each follows the exact
//! accumulation order of its full-graph counterpart:
//!
//! * transforms go through the same column-blocked `matmul_row` kernel
//!   as [`crate::tensor::par_matmul_into`],
//! * the neighbor-mean aggregation accumulates CSR entries in ascending
//!   neighbor order exactly like `CsrMatrix::spmm_into`,
//! * and the layer combines in the [`crate::gnn::Workspace`] SAGE
//!   summation order: neighbor mean first, then the self transform,
//!   then the bias.
//!
//! Consequence: with covering fanouts (every destination's degree ≤ its
//! layer fanout) the sampled logits of the seed nodes are
//! **bit-identical** to the full-graph forward's rows — the property
//! the sampled-serving agreement test pins down.

use crate::gnn::{layer_views, ModelKind};
use crate::tensor::Matrix;
use crate::{eyre, Result};

use super::sampler::Block;

/// Resize `m` to (rows, cols) zero-filled, reusing its allocation.
/// Bumps `grows` when the flat size exceeds the retained capacity (the
/// steady-state zero-alloc probe).
pub(crate) fn reshape(m: &mut Matrix, rows: usize, cols: usize, grows: &mut u64) {
    let need = rows * cols;
    if need > m.data.capacity() {
        *grows += 1;
    }
    m.data.clear();
    m.data.resize(need, 0.0);
    m.rows = rows;
    m.cols = cols;
}

/// Forward (and, for training, backward) scratch for one worker's
/// sampled SAGE steps.
pub struct BlockForward {
    /// `h[0]`: gathered input features (rows follow `blocks[0].src`);
    /// `h[l]` for l ≥ 1: relu of layer l-1's pre-activation.
    h: Vec<Matrix>,
    /// Pre-activation layer outputs; `z[L-1]` holds the seed logits.
    z: Vec<Matrix>,
    /// Neighbor-transform scratch (all source rows).
    t_nb: Matrix,
    /// Self-transform scratch (destination rows only).
    t_self: Matrix,
    /// Backward: gradient w.r.t. the current layer's pre-activation.
    d_cur: Matrix,
    /// Backward: gradient w.r.t. the current layer's input rows.
    d_h: Matrix,
    /// Backward: transpose-aggregation scatter (`Pᵀ dZ`).
    s: Matrix,
    /// Buffer-capacity growth events (must stop once warmed).
    pub grows: u64,
}

impl Default for BlockForward {
    fn default() -> Self {
        Self::new()
    }
}

impl BlockForward {
    pub fn new() -> Self {
        BlockForward {
            h: Vec::new(),
            z: Vec::new(),
            t_nb: Matrix::zeros(0, 0),
            t_self: Matrix::zeros(0, 0),
            d_cur: Matrix::zeros(0, 0),
            d_h: Matrix::zeros(0, 0),
            s: Matrix::zeros(0, 0),
            grows: 0,
        }
    }

    /// Reshape and expose the input-feature buffer for the caller to
    /// fill with `blocks[0].src`'s rows (from local features, the
    /// cache, or remote pulls).
    pub fn input_mut(&mut self, n_src: usize, d_in: usize) -> &mut Matrix {
        if self.h.is_empty() {
            self.h.push(Matrix::zeros(0, 0));
        }
        let grows = &mut self.grows;
        reshape(&mut self.h[0], n_src, d_in, grows);
        &mut self.h[0]
    }

    /// Run the SAGE forward over `blocks` with the flat SAGE parameter
    /// list; [`BlockForward::input_mut`] must have been filled for this
    /// batch.  Returns the seed logits (`blocks.last().n_dst` rows).
    pub fn forward(&mut self, blocks: &[Block], params: &[Matrix]) -> Result<&Matrix> {
        let layers = layer_views(ModelKind::Sage, params)?;
        if layers.len() != blocks.len() {
            return Err(eyre!(
                "{} sampled blocks for {} model layers",
                blocks.len(),
                layers.len()
            ));
        }
        let n_layers = layers.len();
        while self.h.len() < n_layers {
            self.h.push(Matrix::zeros(0, 0));
        }
        while self.z.len() < n_layers {
            self.z.push(Matrix::zeros(0, 0));
        }
        for (l, (b, layer)) in blocks.iter().zip(&layers).enumerate() {
            let last = l + 1 == n_layers;
            // lint:allow(D002, layer_views for Sage always carries a neighbor transform)
            let w_nb = layer.w_nb.expect("SAGE layer views carry w_nb");
            let d_out = layer.w.cols;
            let h = &self.h[l];
            if h.rows != b.n_src() {
                return Err(eyre!(
                    "layer {l}: input rows {} != block src {}",
                    h.rows,
                    b.n_src()
                ));
            }
            if h.cols != layer.w.rows {
                return Err(eyre!(
                    "layer {l}: input width {} != weight rows {}",
                    h.cols,
                    layer.w.rows
                ));
            }
            reshape(&mut self.t_nb, b.n_src(), d_out, &mut self.grows);
            self.h[l].matmul_into(w_nb, &mut self.t_nb);
            reshape(&mut self.t_self, b.n_dst, d_out, &mut self.grows);
            matmul_first_into(&self.h[l], b.n_dst, layer.w, &mut self.t_self);
            reshape(&mut self.z[l], b.n_dst, d_out, &mut self.grows);
            block_spmm_into(b, &self.t_nb, &mut self.z[l]);
            // summation-order contract (`gnn::Workspace` SAGE arm):
            // neighbor mean first, then self transform, then bias
            let z = &mut self.z[l];
            for (o, v) in z.data.iter_mut().zip(&self.t_self.data) {
                *o += *v;
            }
            for r in 0..z.rows {
                let row = &mut z.data[r * z.cols..(r + 1) * z.cols];
                for (o, bv) in row.iter_mut().zip(&layer.b.data) {
                    *o += *bv;
                }
            }
            if !last {
                let (rows, cols) = (self.z[l].rows, self.z[l].cols);
                reshape(&mut self.h[l + 1], rows, cols, &mut self.grows);
                for (h, &v) in self.h[l + 1].data.iter_mut().zip(&self.z[l].data) {
                    *h = v.max(0.0); // relu
                }
            }
        }
        Ok(&self.z[n_layers - 1])
    }

    /// Seed logits of the last [`BlockForward::forward`] call.
    pub fn logits(&self) -> &Matrix {
        &self.z[self.z.len() - 1]
    }

    /// Backward pass for the last forward: masked softmax cross-entropy
    /// over the seed rows against `labels` (one per seed, in
    /// `blocks.last().src[..n_dst]` order), writing the flat SAGE
    /// gradient list `[l0_w, l0_b, l0_nb_w, l1_w, ...]` into `grads`
    /// (shapes must match `params`).  Returns the mean batch loss.
    pub fn backward(
        &mut self,
        blocks: &[Block],
        params: &[Matrix],
        labels: &[u32],
        grads: &mut [Matrix],
    ) -> Result<f32> {
        let layers = layer_views(ModelKind::Sage, params)?;
        if grads.len() != params.len() {
            return Err(eyre!("{} grads for {} params", grads.len(), params.len()));
        }
        let n_layers = layers.len();
        let logits = &self.z[n_layers - 1];
        if labels.len() != logits.rows {
            return Err(eyre!(
                "{} labels for {} seed rows",
                labels.len(),
                logits.rows
            ));
        }
        let grows = &mut self.grows;
        reshape(&mut self.d_cur, logits.rows, logits.cols, grows);
        let loss = softmax_xent_into(logits, labels, &mut self.d_cur)?;
        for l in (0..n_layers).rev() {
            let b = &blocks[l];
            let layer = &layers[l];
            // lint:allow(D002, layer_views for Sage always carries a neighbor transform)
            let w_nb = layer.w_nb.expect("SAGE layer views carry w_nb");
            let h = &self.h[l];
            let d = &self.d_cur;
            // dW_self = H[..n_dst]ᵀ @ dZ
            matmul_tn_first_into(h, b.n_dst, d, &mut grads[3 * l]);
            // db = column sums of dZ
            let gb = &mut grads[3 * l + 1];
            gb.data.fill(0.0);
            for r in 0..d.rows {
                for (o, &v) in gb.data.iter_mut().zip(d.row(r)) {
                    *o += v;
                }
            }
            // S = Pᵀ @ dZ (scatter over sampled edges)
            reshape(&mut self.s, b.n_src(), d.cols, &mut self.grows);
            for r in 0..b.n_dst {
                let drow = &self.d_cur.data[r * self.d_cur.cols..(r + 1) * self.d_cur.cols];
                for e in b.row_ptr[r]..b.row_ptr[r + 1] {
                    let c = b.cols[e] as usize;
                    let val = b.vals[e];
                    let srow = &mut self.s.data[c * d.cols..(c + 1) * d.cols];
                    for (o, &v) in srow.iter_mut().zip(drow) {
                        *o += val * v;
                    }
                }
            }
            // dW_nb = Hᵀ @ S
            matmul_tn_first_into(h, h.rows, &self.s, &mut grads[3 * l + 2]);
            if l > 0 {
                // dH = S @ W_nbᵀ; destination rows also get dZ @ W_selfᵀ
                reshape(&mut self.d_h, b.n_src(), h.cols, &mut self.grows);
                matmul_nt_into(&self.s, w_nb, &mut self.d_h);
                matmul_nt_add_first(&self.d_cur, layer.w, b.n_dst, &mut self.d_h);
                // chain through the relu: dZ_{l-1} = dH ⊙ [z_{l-1} > 0]
                let z_prev = &self.z[l - 1];
                debug_assert_eq!(z_prev.rows, self.d_h.rows);
                for (o, &z) in self.d_h.data.iter_mut().zip(&z_prev.data) {
                    if z <= 0.0 {
                        *o = 0.0;
                    }
                }
                std::mem::swap(&mut self.d_cur, &mut self.d_h);
            }
        }
        Ok(loss)
    }
}

/// FLOPs of one sampled forward over `blocks` with layer widths `dims`
/// (`[d_in, d_h, .., n_class]`): two dense transforms plus the sampled
/// aggregation per layer.
pub fn block_flops(blocks: &[Block], dims: &[usize]) -> u64 {
    let mut f = 0u64;
    for (l, b) in blocks.iter().enumerate() {
        let (di, dn) = (dims[l] as u64, dims[l + 1] as u64);
        f += 2 * b.n_src() as u64 * di * dn; // neighbor transform
        f += 2 * b.n_dst as u64 * di * dn; // self transform
        f += 2 * b.nnz() as u64 * dn; // sampled-mean aggregation
    }
    f
}

/// `out[..n_rows] = a[..n_rows] @ b` via the shared row kernel.
fn matmul_first_into(a: &Matrix, n_rows: usize, b: &Matrix, out: &mut Matrix) {
    debug_assert_eq!(a.cols, b.rows);
    debug_assert!(out.rows == n_rows && out.cols == b.cols);
    for i in 0..n_rows {
        crate::tensor::matmul_row(
            a.row(i),
            &b.data,
            b.cols,
            &mut out.data[i * b.cols..(i + 1) * b.cols],
        );
    }
}

/// `out = a[..n_rows]ᵀ @ b` (out is (a.cols, b.cols), fully rewritten).
fn matmul_tn_first_into(a: &Matrix, n_rows: usize, b: &Matrix, out: &mut Matrix) {
    debug_assert!(b.rows >= n_rows);
    debug_assert!(out.rows == a.cols && out.cols == b.cols);
    out.data.fill(0.0);
    for r in 0..n_rows {
        let arow = a.row(r);
        let brow = b.row(r);
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out.data[i * out.cols..(i + 1) * out.cols];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out = a @ bᵀ` (row-wise dot products; out fully rewritten).
fn matmul_nt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    debug_assert_eq!(a.cols, b.cols);
    debug_assert!(out.rows == a.rows && out.cols == b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * b.rows..(i + 1) * b.rows];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
}

/// `out[..n_rows] += a @ bᵀ` (the destination-row self-transform term
/// of the input gradient).
fn matmul_nt_add_first(a: &Matrix, b: &Matrix, n_rows: usize, out: &mut Matrix) {
    debug_assert_eq!(a.rows, n_rows);
    debug_assert_eq!(a.cols, b.cols);
    for i in 0..n_rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * out.cols..(i + 1) * out.cols];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o += acc;
        }
    }
}

/// `out[..n_dst] = block × dense` (the sampled-mean aggregation),
/// accumulating each row's CSR entries in ascending-neighbor order —
/// the same order `CsrMatrix::spmm_into` uses.
fn block_spmm_into(b: &Block, dense: &Matrix, out: &mut Matrix) {
    debug_assert!(out.rows == b.n_dst && out.cols == dense.cols);
    let d = dense.cols;
    for r in 0..b.n_dst {
        let orow = &mut out.data[r * d..(r + 1) * d];
        orow.fill(0.0);
        for e in b.row_ptr[r]..b.row_ptr[r + 1] {
            let val = b.vals[e];
            let drow = dense.row(b.cols[e] as usize);
            for (o, &x) in orow.iter_mut().zip(drow) {
                *o += val * x;
            }
        }
    }
}

/// Masked softmax cross-entropy over all rows of `logits`: writes the
/// mean-scaled gradient `(softmax - onehot) / rows` into `d` and
/// returns the mean loss.
fn softmax_xent_into(logits: &Matrix, labels: &[u32], d: &mut Matrix) -> Result<f32> {
    debug_assert!(d.rows == logits.rows && d.cols == logits.cols);
    if logits.rows == 0 {
        return Ok(0.0);
    }
    let scale = 1.0 / logits.rows as f32;
    let mut loss = 0.0f64;
    for r in 0..logits.rows {
        let row = logits.row(r);
        let y = labels[r] as usize;
        if y >= row.len() {
            return Err(eyre!("label {y} out of range for {} classes", row.len()));
        }
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        let drow = &mut d.data[r * logits.cols..(r + 1) * logits.cols];
        for (o, &z) in drow.iter_mut().zip(row) {
            let e = (z - max).exp();
            *o = e;
            sum += e;
        }
        loss += (sum.ln() - (row[y] - max)) as f64;
        let inv = 1.0 / sum;
        for o in drow.iter_mut() {
            *o *= inv * scale;
        }
        drow[y] -= scale;
    }
    Ok(loss as f32 * scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::registry::load;
    use crate::sample::sampler::BlockSampler;
    use crate::util::Rng;

    /// Gather rows of `src` ids from the dataset features.
    fn gather(fw: &mut BlockForward, feats: &Matrix, src: &[u32]) {
        let x = fw.input_mut(src.len(), feats.cols);
        for (i, &u) in src.iter().enumerate() {
            x.copy_row_from(i, feats.row(u as usize));
        }
    }

    fn sage_params(dims: &[usize], seed: u64) -> Vec<Matrix> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for l in 0..dims.len() - 1 {
            out.push(Matrix::glorot(dims[l], dims[l + 1], &mut rng));
            out.push(Matrix::zeros(1, dims[l + 1]));
            out.push(Matrix::glorot(dims[l], dims[l + 1], &mut rng));
        }
        out
    }

    #[test]
    fn covering_fanout_matches_full_graph_forward_bitwise() {
        let ds = load("karate", 0).unwrap();
        let dims = [ds.features.cols, 8, ds.n_class];
        let params = sage_params(&dims, 5);
        let (full, _) = crate::gnn::forward_t(
            ModelKind::Sage,
            &ds.graph,
            &ds.features,
            &params,
            false,
            1,
        )
        .unwrap();
        let max_deg = ds.graph.max_degree();
        let mut s = BlockSampler::new(ds.n());
        let mut rng = Rng::new(1);
        let seeds = [3u32, 0, 33, 12];
        s.sample_batch(&ds.graph, &[max_deg, max_deg], &seeds, None, &mut rng);
        let mut fw = BlockForward::new();
        gather(&mut fw, &ds.features, &s.blocks[0].src);
        let logits = fw.forward(&s.blocks, &params).unwrap();
        for (i, &v) in seeds.iter().enumerate() {
            assert_eq!(
                logits.row(i),
                full.row(v as usize),
                "seed {v} logits differ from the full-graph forward"
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let ds = load("karate", 0).unwrap();
        let dims = [ds.features.cols, 4, ds.n_class];
        let mut params = sage_params(&dims, 9);
        let mut s = BlockSampler::new(ds.n());
        let mut rng = Rng::new(2);
        let seeds = [1u32, 8, 30];
        s.sample_batch(&ds.graph, &[3, 4], &seeds, None, &mut rng);
        let labels: Vec<u32> = s.blocks[1].src[..s.blocks[1].n_dst]
            .iter()
            .map(|&v| ds.labels[v as usize])
            .collect();
        let mut fw = BlockForward::new();
        let loss_at = |fw: &mut BlockForward, params: &[Matrix]| -> f32 {
            gather(fw, &ds.features, &s.blocks[0].src);
            fw.forward(&s.blocks, params).unwrap();
            let logits = fw.logits();
            let mut l = 0.0f32;
            let n = logits.rows as f32;
            for r in 0..logits.rows {
                let row = logits.row(r);
                let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let sum: f32 = row.iter().map(|&z| (z - max).exp()).sum();
                l += sum.ln() - (row[labels[r] as usize] - max);
            }
            l / n
        };
        let mut grads: Vec<Matrix> =
            params.iter().map(|p| Matrix::zeros(p.rows, p.cols)).collect();
        gather(&mut fw, &ds.features, &s.blocks[0].src);
        fw.forward(&s.blocks, &params).unwrap();
        let loss = fw
            .backward(&s.blocks, &params, &labels, &mut grads)
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // spot-check a handful of coordinates in every parameter tensor
        let eps = 1e-2f32;
        for pi in 0..params.len() {
            for &(r, c) in &[(0usize, 0usize), (1, 1)] {
                if r >= params[pi].rows || c >= params[pi].cols {
                    continue;
                }
                let orig = params[pi].get(r, c);
                params[pi].set(r, c, orig + eps);
                let up = loss_at(&mut fw, &params);
                params[pi].set(r, c, orig - eps);
                let down = loss_at(&mut fw, &params);
                params[pi].set(r, c, orig);
                let want = (up - down) / (2.0 * eps);
                let got = grads[pi].get(r, c);
                assert!(
                    (got - want).abs() <= 2e-2 + 0.1 * want.abs(),
                    "param {pi} ({r},{c}): analytic {got} vs numeric {want}"
                );
            }
        }
    }

    #[test]
    fn warmed_forward_backward_allocates_nothing() {
        let ds = load("arxiv-s", 0).unwrap();
        let dims = [ds.features.cols, 16, ds.n_class];
        let params = sage_params(&dims, 3);
        let mut grads: Vec<Matrix> =
            params.iter().map(|p| Matrix::zeros(p.rows, p.cols)).collect();
        let mut s = BlockSampler::new(ds.n());
        let mut fw = BlockForward::new();
        let seeds: Vec<u32> = (0..32u32).collect();
        let labels_of = |src: &[u32], n_dst: usize| -> Vec<u32> {
            src[..n_dst].iter().map(|&v| ds.labels[v as usize]).collect()
        };
        // re-seed per batch so every batch shapes the same blocks: the
        // assertion then isolates buffer *reuse* from the (amortized)
        // capacity high-water a stochastic batch stream ratchets up
        let mut step = |s: &mut BlockSampler, fw: &mut BlockForward| {
            let mut rng = Rng::new(4);
            s.sample_batch(&ds.graph, &[5, 10], &seeds, None, &mut rng);
            let x = fw.input_mut(s.blocks[0].src.len(), ds.features.cols);
            for (i, &u) in s.blocks[0].src.iter().enumerate() {
                x.copy_row_from(i, ds.features.row(u as usize));
            }
            fw.forward(&s.blocks, &params).unwrap();
            let labels = labels_of(&s.blocks[1].src, s.blocks[1].n_dst);
            fw.backward(&s.blocks, &params, &labels, &mut grads).unwrap();
        };
        step(&mut s, &mut fw);
        let warm = fw.grows;
        for _ in 0..6 {
            step(&mut s, &mut fw);
        }
        assert_eq!(fw.grows, warm, "steady-state step grew a matrix buffer");
    }
}
