//! [`SampledSession`]: mini-batch neighbor-sampled GraphSAGE training
//! behind the standard [`TrainSession`] API.
//!
//! One *epoch* is a fixed number of synchronous *rounds*; each round,
//! every worker samples one mini-batch from its partition's training
//! nodes (partition-aware, local-first), gathers exact layer-0
//! features (local rows directly, remote rows through its
//! [`FeatureCache`] over the representation plane), runs the pure-Rust
//! SAGE forward/backward, and submits gradients to the shared
//! parameter server.  The virtual clock reuses the sync scheduler's
//! arithmetic ([`aggregate_epoch`]) with one barrier per round.
//!
//! Determinism: each worker owns its sampling and straggler RNG
//! streams, all per-worker math is sequential, and the PS reduces
//! gradient slots in ascending worker order — so checkpoints are
//! bit-identical at any thread count, and a resumed run replays the
//! exact epoch stream (worker RNG states, cache tables and every
//! counter ride in the checkpoint's `extra` block).

use std::time::Instant;

use crate::coordinator::context::TrainContext;
use crate::coordinator::engine::{for_each_mut, resolve_threads};
use crate::coordinator::session::{base_state, state_checkpoint, EpochReport, TrainSession};
use crate::coordinator::sync::{aggregate_epoch, StepReport};
use crate::coordinator::telemetry::{EpochBreakdown, LogPoint, RunResult};
use crate::graph::Split;
use crate::ps::checkpoint::{rng_from_json, Checkpoint, TrainState};
use crate::ps::{optimizer::Optimizer, ParamServer};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::{domain_seed, Rng};
use crate::{eyre, Result};

use super::cache::FeatureCache;
use super::forward::{block_flops, reshape, BlockForward};
use super::sampler::BlockSampler;

/// Per-worker state of the sampled trainer: sampling stream, feature
/// cache, forward/backward scratch and the gradient buffers it submits.
struct SampleWorker {
    id: usize,
    home: u32,
    /// Training nodes of this worker's partition (ascending).
    train_nodes: Vec<u32>,
    /// Per-epoch shuffled permutation of `train_nodes`, consumed with
    /// wrap-around so every round has a full batch.
    perm: Vec<u32>,
    cursor: usize,
    /// Drives the epoch shuffle and all neighbor sampling.
    rng: Rng,
    /// Separate stream for straggler delays (keeps sampling draws
    /// independent of the cost model's).
    straggle_rng: Rng,
    sampler: BlockSampler,
    fw: BlockForward,
    cache: FeatureCache,
    grads: Vec<Matrix>,
    /// Layer widths `[d_in, d_h, .., n_class]` (cached off the spec).
    dims: Vec<usize>,
    seeds: Vec<u32>,
    labels: Vec<u32>,
    /// (input row, node) pairs the cache missed this batch.
    miss_rows: Vec<(usize, u32)>,
    pull_nodes: Vec<u32>,
    pull_buf: Matrix,
    grows: u64,
}

impl SampleWorker {
    fn new(ctx: &TrainContext, id: usize, params: &[Matrix]) -> Self {
        let cfg = &ctx.cfg;
        let ds = &ctx.ds;
        let train_nodes: Vec<u32> = (0..ds.n())
            .filter(|&v| {
                ctx.partition.parts[v] == id as u32 && ds.split[v] == Split::Train
            })
            .map(|v| v as u32)
            .collect();
        let mix = (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        SampleWorker {
            id,
            home: id as u32,
            perm: train_nodes.clone(),
            train_nodes,
            cursor: 0,
            rng: Rng::new(domain_seed(cfg.seed, "sample-worker") ^ mix),
            straggle_rng: Rng::new(domain_seed(cfg.seed, "sample-straggle") ^ mix),
            sampler: BlockSampler::new(ds.n()),
            fw: BlockForward::new(),
            cache: FeatureCache::new(ds.n(), ds.features.cols, cfg.cache_nodes),
            grads: params
                .iter()
                .map(|p| Matrix::zeros(p.rows, p.cols))
                .collect(),
            dims: ctx.spec.dims(),
            seeds: Vec::new(),
            labels: Vec::new(),
            miss_rows: Vec::new(),
            pull_nodes: Vec::new(),
            pull_buf: Matrix::zeros(0, 0),
            grows: 0,
        }
    }

    /// Reshuffle the training permutation for a new epoch.
    fn begin_epoch(&mut self) {
        self.perm.clear();
        self.perm.extend_from_slice(&self.train_nodes);
        self.rng.shuffle(&mut self.perm);
        self.cursor = 0;
    }

    /// Sample, gather, forward, backward one mini-batch; gradients land
    /// in `self.grads` ready for slot submission.
    fn run_batch(&mut self, ctx: &TrainContext, params: &[Matrix]) -> Result<StepReport> {
        let cfg = &ctx.cfg;
        self.seeds.clear();
        if !self.perm.is_empty() {
            for _ in 0..cfg.batch_size {
                self.seeds.push(self.perm[self.cursor]);
                self.cursor = (self.cursor + 1) % self.perm.len();
            }
        }
        self.sampler.sample_batch(
            &ctx.ds.graph,
            &cfg.fanouts,
            &self.seeds,
            Some((&ctx.partition.parts, self.home)),
            &mut self.rng,
        );
        let io_bytes = self.gather_features(ctx)?;
        let loss = if self.seeds.is_empty() {
            // a partition with no training nodes still participates in
            // the round barrier: it submits an exact zero gradient
            for g in &mut self.grads {
                g.data.fill(0.0);
            }
            0.0
        } else {
            self.fw.forward(&self.sampler.blocks, params)?;
            let top = &self.sampler.blocks[self.sampler.blocks.len() - 1];
            self.labels.clear();
            self.labels.extend(
                top.src[..top.n_dst]
                    .iter()
                    .map(|&v| ctx.ds.labels[v as usize]),
            );
            self.fw
                .backward(&self.sampler.blocks, params, &self.labels, &mut self.grads)?
        };
        let flops = 3 * block_flops(&self.sampler.blocks, &self.dims);
        let compute_t = ctx.cost.compute_time(self.id, flops);
        let pull_io = if io_bytes > 0 {
            ctx.cost.comm_time(io_bytes)
        } else {
            0.0
        };
        Ok(StepReport {
            loss,
            compute_t,
            pull_io,
            push_io: 0.0,
            straggle: ctx.cost.straggler_delay(self.id, &mut self.straggle_rng),
            // sampled training consumes exact features only — nothing
            // stale to age
            stale_age: None,
        })
    }

    /// Fill the forward's input buffer with `blocks[0].src`'s feature
    /// rows: local rows straight from the dataset, remote rows through
    /// the cache, cache misses in one batched pull over the
    /// representation plane.  Returns the bytes pulled remotely.
    fn gather_features(&mut self, ctx: &TrainContext) -> Result<u64> {
        let d_in = ctx.ds.features.cols;
        let src = &self.sampler.blocks[0].src;
        let x = self.fw.input_mut(src.len(), d_in);
        self.miss_rows.clear();
        self.pull_nodes.clear();
        for (i, &u) in src.iter().enumerate() {
            if ctx.partition.parts[u as usize] == self.home {
                x.copy_row_from(i, ctx.ds.features.row(u as usize));
            } else if !self.cache.lookup(u, x.row_mut(i)) {
                self.miss_rows.push((i, u));
                self.pull_nodes.push(u);
            }
        }
        if self.pull_nodes.is_empty() {
            return Ok(0);
        }
        reshape(&mut self.pull_buf, self.pull_nodes.len(), d_in, &mut self.grows);
        let info = ctx.kvs.pull_into(0, &self.pull_nodes, &mut self.pull_buf)?;
        if info.missing > 0 {
            return Err(eyre!(
                "{} feature rows missing from the representation plane \
                 (features are pushed at session start; a missing row is a bug)",
                info.missing
            ));
        }
        for (k, &(i, u)) in self.miss_rows.iter().enumerate() {
            let row = self.pull_buf.row(k);
            x.copy_row_from(i, row);
            self.cache.admit(u, row);
        }
        let bytes = (self.pull_nodes.len() * d_in * 4) as u64;
        self.cache.bytes += bytes;
        Ok(bytes)
    }
}

/// Mini-batch neighbor-sampled GraphSAGE training as a stepwise state
/// machine ([`TrainSession`]).
pub struct SampledSession<'a> {
    ctx: &'a TrainContext,
    threads: usize,
    ps: ParamServer,
    workers: Vec<SampleWorker>,
    /// Synchronous mini-batch rounds per epoch.
    rounds: usize,
    t0: Instant,
    r: usize,
    vtime: f64,
    ps_bytes: u64,
    points: Vec<LogPoint>,
    breakdowns: Vec<EpochBreakdown>,
    best_val: f64,
    final_val: f64,
    final_test: f64,
}

impl<'a> SampledSession<'a> {
    pub fn new(ctx: &'a TrainContext) -> Result<Self> {
        let s = Self::build(ctx)?;
        push_features(ctx)?;
        Ok(s)
    }

    fn build(ctx: &'a TrainContext) -> Result<Self> {
        let cfg = &ctx.cfg;
        let params = ctx.initial_params();
        let workers: Vec<SampleWorker> = (0..cfg.parts)
            .map(|id| SampleWorker::new(ctx, id, &params))
            .collect();
        let rounds = workers
            .iter()
            .map(|w| w.train_nodes.len().div_ceil(cfg.batch_size))
            .max()
            .unwrap_or(1)
            .max(1);
        Ok(SampledSession {
            ctx,
            threads: resolve_threads(cfg.threads, cfg.parts),
            ps: ParamServer::new(
                params,
                Optimizer::new(cfg.optimizer, cfg.lr).with_weight_decay(cfg.weight_decay),
                cfg.parts,
            ),
            workers,
            rounds,
            // lint:allow(D006, observational wall-clock anchor for telemetry columns only; never feeds training math)
            t0: Instant::now(),
            r: 0,
            vtime: 0.0,
            ps_bytes: 0,
            points: Vec::new(),
            breakdowns: Vec::new(),
            best_val: 0.0,
            final_val: f64::NAN,
            final_test: f64::NAN,
        })
    }

    /// Rebuild from a v2 checkpoint state.  The KVS (feature plane) is
    /// restored by [`crate::coordinator::session::resume_session`], so
    /// features are *not* re-pushed — traffic metrics continue exactly
    /// where the checkpoint left them.  Worker RNG streams and cache
    /// tables come out of the checkpoint's `extra` block, which is what
    /// makes resumed epochs bit-identical to uninterrupted ones.
    pub fn resume(ctx: &'a TrainContext, state: &TrainState) -> Result<Self> {
        let mut s = Self::build(ctx)?;
        s.ps.import_state(&state.ps);
        let ws = state.extra.get("workers")?.as_arr()?;
        if ws.len() != s.workers.len() {
            return Err(eyre!(
                "checkpoint has {} sampled workers, config wants {}",
                ws.len(),
                s.workers.len()
            ));
        }
        for (w, j) in s.workers.iter_mut().zip(ws) {
            w.rng = Rng::from_state(rng_from_json(j.get("rng")?)?);
            w.straggle_rng = Rng::from_state(rng_from_json(j.get("straggle_rng")?)?);
            w.cache.import_json(j.get("cache")?, &ctx.ds.features)?;
        }
        s.r = state.epoch;
        s.vtime = state.vtime;
        s.ps_bytes = state.ps_bytes;
        s.best_val = state.best_val_f1;
        s.final_val = state.final_val_f1;
        s.final_test = state.final_test_f1;
        Ok(s)
    }

    /// Cumulative cache counters summed over workers (worker-id order).
    fn cache_totals(&self) -> (u64, u64, u64) {
        let mut t = (0u64, 0u64, 0u64);
        for w in &self.workers {
            t.0 += w.cache.hits;
            t.1 += w.cache.misses;
            t.2 += w.cache.bytes;
        }
        t
    }
}

impl TrainSession for SampledSession<'_> {
    fn ctx(&self) -> &TrainContext {
        self.ctx
    }

    fn epochs_done(&self) -> usize {
        self.r
    }

    fn step_epoch(&mut self) -> Result<EpochReport> {
        if self.is_done() {
            return Err(eyre!("session already ran {} epochs", self.r));
        }
        let ctx = self.ctx;
        let cfg = &ctx.cfg;
        let r = self.r;
        let mut epoch_bd = EpochBreakdown::default();
        let mut loss_accum = 0.0f64;
        let mut n_reports = 0usize;
        for round in 0..self.rounds {
            let (params, _) = self.ps.fetch();
            let ps = &self.ps;
            let first = round == 0;
            let reports = for_each_mut(self.threads, &mut self.workers, |w| {
                if first {
                    w.begin_epoch();
                }
                let rep = w.run_batch(ctx, &params)?;
                ps.submit_slot(w.id, &w.grads);
                Ok(rep)
            })?;
            let (bd, loss_sum) = aggregate_epoch(ctx, &reports);
            self.ps_bytes += reports.len() as u64 * 2 * ctx.param_bytes();
            self.vtime += bd.total;
            loss_accum += loss_sum;
            n_reports += reports.len();
            epoch_bd.compute += bd.compute;
            epoch_bd.kvs_io += bd.kvs_io;
            epoch_bd.ps_io += bd.ps_io;
            epoch_bd.straggle += bd.straggle;
            epoch_bd.total += bd.total;
        }
        self.breakdowns.push(epoch_bd);

        let evaluate = r % cfg.eval_every == 0 || r + 1 == cfg.epochs;
        let (val, test) = if evaluate {
            let (p, _) = self.ps.fetch();
            let (v, t) = ctx.global_eval(&p)?;
            self.best_val = self.best_val.max(v);
            self.final_val = v;
            self.final_test = t;
            (v, t)
        } else {
            (f64::NAN, f64::NAN)
        };
        let (hits, misses, bytes) = self.cache_totals();
        let point = LogPoint {
            epoch: r,
            vtime: self.vtime,
            wall: self.t0.elapsed().as_secs_f64(),
            train_loss: loss_accum / n_reports.max(1) as f64,
            val_f1: val,
            test_f1: test,
            kvs_bytes: ctx.kvs.metrics().total_bytes(),
            ps_bytes: self.ps_bytes,
            wire_bytes: ctx.kvs.wire_bytes(),
            wire_retries: 0,
            leases_lost: 0,
            cache_hits: hits,
            cache_misses: misses,
            cache_bytes: bytes,
        };
        self.points.push(point.clone());
        self.r += 1;
        Ok(EpochReport {
            epoch: r,
            target_epochs: cfg.epochs,
            point,
            breakdown: epoch_bd,
            // every round is a synchronous barrier on fresh parameters
            synced: true,
            evaluated: evaluate,
            best_val_f1: self.best_val,
        })
    }

    fn current_params(&self) -> Vec<Matrix> {
        self.ps.fetch().0
    }

    fn best_val_f1(&self) -> f64 {
        self.best_val
    }

    fn snapshot(&self) -> Result<Checkpoint> {
        let mut state = base_state(self.ctx, "sampled")?;
        state.epoch = self.r;
        state.vtime = self.vtime;
        state.ps_bytes = self.ps_bytes;
        state.best_val_f1 = self.best_val;
        state.final_val_f1 = self.final_val;
        state.final_test_f1 = self.final_test;
        state.ps = self.ps.export_state();
        // the sampled trainer has no stale-rep worker caches; its
        // per-worker state (RNG streams + feature cache) rides in extra
        state.workers = Vec::new();
        let rng_json = |rng: &Rng| {
            Json::Arr(rng.state().iter().map(|&x| Json::uint(x)).collect())
        };
        state.extra = Json::obj(vec![(
            "workers",
            Json::Arr(
                self.workers
                    .iter()
                    .map(|w| {
                        Json::obj(vec![
                            ("cache", w.cache.export_json()),
                            ("rng", rng_json(&w.rng)),
                            ("straggle_rng", rng_json(&w.straggle_rng)),
                        ])
                    })
                    .collect(),
            ),
        )]);
        Ok(state_checkpoint(self.ctx, state))
    }

    fn finish(&mut self) -> Result<RunResult> {
        let cfg = &self.ctx.cfg;
        Ok(RunResult {
            method: "sampled".to_string(),
            dataset: cfg.dataset.clone(),
            model: cfg.model.as_str().to_string(),
            parts: cfg.parts,
            // features are exact every round; there is no periodic
            // stale-sync interval in this method
            sync_interval: 1,
            threads: self.threads,
            seed: cfg.seed,
            points: std::mem::take(&mut self.points),
            epochs: std::mem::take(&mut self.breakdowns),
            final_val_f1: self.final_val,
            final_test_f1: self.final_test,
            best_val_f1: self.best_val,
            total_vtime: self.vtime,
            total_wall: self.t0.elapsed().as_secs_f64(),
            kvs: self.ctx.kvs.metrics(),
            delay: self.ps.delay_stats(),
            final_params: self.ps.fetch().0,
        })
    }
}

/// Populate the representation plane with every partition's layer-0
/// feature rows (each owner pushes its own partition, version 0).
/// Sampled training then pulls only *remote* rows through the caches.
fn push_features(ctx: &TrainContext) -> Result<()> {
    let d_in = ctx.ds.features.cols;
    for m in 0..ctx.partition.k {
        let members = ctx.partition.members(m);
        let mut rows = Matrix::zeros(members.len(), d_in);
        for (i, &v) in members.iter().enumerate() {
            rows.copy_row_from(i, ctx.ds.features.row(v as usize));
        }
        ctx.kvs.push(0, &members, &rows, 0)?;
    }
    Ok(())
}

/// Run sampled training to completion (one-shot convenience over
/// [`SampledSession`]).
pub fn run_sampled(ctx: &TrainContext) -> Result<RunResult> {
    let mut s = SampledSession::new(ctx)?;
    while !s.is_done() {
        s.step_epoch()?;
    }
    s.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, RunConfig};
    use crate::gnn::ModelKind;

    fn sampled_cfg(epochs: usize) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.method = Method::Sampled;
        cfg.model = ModelKind::Sage;
        cfg.epochs = epochs;
        cfg.eval_every = 5;
        cfg.fanouts = vec![5, 5];
        cfg.batch_size = 8;
        cfg.hidden = vec![16];
        cfg
    }

    #[test]
    fn sampled_learns_karate() {
        let ctx = TrainContext::new(sampled_cfg(30)).unwrap();
        let res = run_sampled(&ctx).unwrap();
        assert_eq!(res.method, "sampled");
        assert!(res.best_val_f1 > 0.5, "best val {}", res.best_val_f1);
        assert!(res.total_vtime > 0.0);
        let last = res.points.last().unwrap();
        assert!(last.train_loss.is_finite());
        // remote features were actually pulled (cross-partition batch)
        assert!(last.cache_misses > 0 || last.cache_hits > 0);
    }

    #[test]
    fn loss_decreases_over_training() {
        let mut cfg = sampled_cfg(20);
        cfg.eval_every = 100;
        let ctx = TrainContext::new(cfg).unwrap();
        let res = run_sampled(&ctx).unwrap();
        let losses: Vec<f64> = res.points.iter().map(|p| p.train_loss).collect();
        assert!(
            losses.last().unwrap() < &losses[0],
            "loss did not decrease: {losses:?}"
        );
    }

    #[test]
    fn cache_serves_repeat_remote_neighbors() {
        let mut cfg = sampled_cfg(6);
        cfg.dataset = "arxiv-s".into();
        cfg.parts = 4;
        cfg.cache_nodes = 512;
        let ctx = TrainContext::new(cfg).unwrap();
        let res = run_sampled(&ctx).unwrap();
        let last = res.points.last().unwrap();
        assert!(last.cache_hits > 0, "cache never hit: {last:?}");
        assert!(last.cache_bytes > 0);
        // cumulative counters are monotone across epochs
        for w in res.points.windows(2) {
            assert!(w[1].cache_hits >= w[0].cache_hits);
            assert!(w[1].cache_bytes >= w[0].cache_bytes);
        }
    }
}
