//! Tiny property-testing driver (proptest is not in the offline crate
//! cache).  Runs a property over many seeded random cases and reports
//! the first failing seed so failures are reproducible; no shrinking.
//!
//! ```ignore
//! prop_check(100, |rng| {
//!     let n = 2 + rng.below(50);
//!     let g = random_graph(rng, n);
//!     check_invariant(&g)
//! });
//! ```

use super::Rng;

/// Run `cases` random trials of `property`; panic with the failing seed
/// and message on the first violation.  `property` returns
/// `Err(message)` to signal failure.
pub fn prop_check(cases: u64, mut property: impl FnMut(&mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xD1_6E57 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = property(&mut rng) {
            // lint:allow(D002, the property harness reports failures by panicking; that is its contract with the test runner)
            panic!("property failed at case {seed}: {msg}");
        }
    }
}

/// Assert helper returning Err instead of panicking (for prop_check).
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        prop_check(50, |rng| {
            let a = rng.below(100);
            prop_assert!(a < 100, "below out of range: {a}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn panics_with_seed_on_failure() {
        prop_check(50, |rng| {
            let a = rng.below(100);
            prop_assert!(a < 50, "half the draws fail: {a}");
            Ok(())
        });
    }
}
