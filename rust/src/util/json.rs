//! Minimal JSON parser/emitter.
//!
//! The crate cache in this environment has no `serde`/`serde_json`
//! (DESIGN.md §2, Cargo.toml note), and the only JSON the library needs
//! is the artifact manifest written by `python/compile/aot.py` plus the
//! experiment result files it emits itself — a few hundred lines of
//! recursive-descent parser cover that completely.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (the manifest is pure ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{eyre, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Integer literal that is NOT exactly representable as an `f64`
    /// (e.g. a 64-bit seed).  The parser only produces this variant when
    /// routing through `Num` would silently change the value, so every
    /// ordinary number still lives in `Num`.
    Big(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(eyre!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| eyre!("missing key {key:?}")),
            _ => Err(eyre!("not an object (looking up {key:?})")),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(eyre!("not a string: {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            Json::Big(b) => Ok(*b as f64),
            _ => Err(eyre!("not a number: {self:?}")),
        }
    }

    /// Exact u64 accessor.  Unlike `as_f64()? as u64` (which silently
    /// saturates and loses precision above 2^53), this errors on
    /// negative, fractional, or non-round-tripping values — and returns
    /// large integer literals losslessly via [`Json::Big`].
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Big(b) => Ok(*b),
            Json::Num(n) => {
                // upper bound excludes 2^64 itself: the saturating cast
                // below would otherwise map it onto u64::MAX and pass
                // the round-trip check
                if *n < 0.0 || n.fract() != 0.0 || *n >= 18446744073709551616.0 {
                    return Err(eyre!("not a u64-range integer: {n}"));
                }
                let v = *n as u64;
                if v as f64 != *n {
                    return Err(eyre!("integer {n} not exactly representable as u64"));
                }
                Ok(v)
            }
            _ => Err(eyre!("not a number: {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(eyre!("not a bool: {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(eyre!("not an array: {self:?}")),
        }
    }

    // ---- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Lossless u64 constructor: stays in `Num` when the value is
    /// exactly representable as f64, falls back to [`Json::Big`]
    /// otherwise (so `uint(x).as_u64() == x` for every u64).
    pub fn uint(v: u64) -> Json {
        let f = v as f64;
        // the f < 2^64 guard keeps u64::MAX (which rounds UP to 2^64,
        // then saturates back) out of the lossy Num path
        if f < 18446744073709551616.0 && f as u64 == v {
            Json::Num(f)
        } else {
            Json::Big(v)
        }
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    /// Serialize into an existing buffer (appends).  Public so
    /// streaming writers — above all the reusable-buffer checkpoint
    /// serializer in [`crate::ps::checkpoint`] — can emit stack-built
    /// `Json` scalars with the exact same number/escape formatting as a
    /// full tree serialization, without allocating tree nodes.
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/inf literal; bare "NaN" would make
                    // the whole file unparseable, so degrade to null
                    // (readers that expect possibly-NaN fields map null
                    // back to NaN)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Big(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Str(s) => write_str_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str_escaped(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` as a JSON string literal (quoted + escaped) — the one
/// string-escaping implementation shared by tree serialization and the
/// streaming checkpoint writer.
pub fn write_str_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| eyre!("unexpected end of input"))
    }

    fn expect_byte(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(eyre!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.b[self.i] as char
            ));
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(eyre!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(eyre!("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| eyre!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| eyre!("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(eyre!("bad escape \\{}", e as char)),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    if start + len > self.b.len() {
                        return Err(eyre!("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| eyre!("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| eyre!("non-UTF-8 bytes in number at byte {start}"))?;
        // pure-integer literals that would lose bits through f64 (values
        // above 2^53 with low bits set, e.g. 64-bit seeds) are kept
        // exact in `Big`; everything else takes the f64 path as before
        if s.bytes().all(|c| c.is_ascii_digit()) {
            if let Ok(v) = s.parse::<u64>() {
                return Ok(Json::uint(v));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| eyre!("invalid number {s:?} at byte {start}"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => return Err(eyre!("expected ',' or ']' found {:?}", c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect_byte(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => return Err(eyre!("expected ',' or '}}' found {:?}", c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        let inner = &v.get("a").unwrap().as_arr().unwrap()[2];
        assert_eq!(inner.get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":true,"d":"x\"y"},"e":null}"#,
            r#"[0.5,-3,1e10]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "{c}");
        }
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn accessors_error_cleanly() {
        let v = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn u64_round_trips_above_f64_precision() {
        // 2^53 + 1 is the first integer f64 cannot represent: the old
        // as_f64()-based path silently rounded it to 2^53
        let v = Json::parse("9007199254740993").unwrap();
        assert_eq!(v, Json::Big(9007199254740993));
        assert_eq!(v.as_u64().unwrap(), 9007199254740993);
        // a full-width 64-bit seed survives write -> parse -> as_u64
        let seed = 0x9E3779B97F4A7C15u64;
        let j = Json::uint(seed);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_u64().unwrap(), seed);
        // u64::MAX (rounds UP to 2^64 in f64) must take the Big path
        assert_eq!(Json::uint(u64::MAX), Json::Big(u64::MAX));
        assert_eq!(
            Json::parse(&u64::MAX.to_string()).unwrap().as_u64().unwrap(),
            u64::MAX
        );
        // representable integers stay plain numbers
        assert_eq!(Json::uint(1 << 60), Json::Num((1u64 << 60) as f64));
        assert_eq!(Json::parse("42").unwrap().as_u64().unwrap(), 42);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null_not_invalid_json() {
        // bare "NaN"/"inf" would make the whole document unparseable
        let j = Json::Arr(vec![
            Json::Num(f64::NAN),
            Json::Num(f64::INFINITY),
            Json::Num(1.5),
        ]);
        let text = j.to_string();
        assert_eq!(text, "[null,null,1.5]");
        Json::parse(&text).unwrap();
    }

    #[test]
    fn as_u64_rejects_lossy_values() {
        assert!(Json::Num(1.5).as_u64().is_err());
        assert!(Json::Num(-3.0).as_u64().is_err());
        assert!(Json::Num(1e300).as_u64().is_err());
        assert!(Json::Num(18446744073709551616.0).as_u64().is_err());
        assert!(Json::Str("7".into()).as_u64().is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // integration-ish: if make artifacts has run, parse the real thing
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 0);
        }
    }
}
