//! Exact-sample latency histogram for the serving benches.
//!
//! Stores every recorded sample (seconds, f64) rather than bucketed
//! counts: the serve benches record at most a few hundred thousand
//! samples per run, so exactness is cheap — quantiles are true
//! order statistics (nearest-rank), not bucket-boundary estimates,
//! and merging per-thread histograms is lossless concatenation.
//! Log-spaced buckets exist only for display ([`LatencyHistogram::ascii`]).

/// Collects latency samples; see module docs.
#[derive(Debug, Clone, Default)]
pub struct LatencyHistogram {
    samples: Vec<f64>,
}

/// Point-in-time summary of a [`LatencyHistogram`]; all values seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample in seconds.  Non-finite or negative values
    /// (clock anomalies) are dropped rather than poisoning quantiles.
    pub fn record(&mut self, secs: f64) {
        if secs.is_finite() && secs >= 0.0 {
            self.samples.push(secs);
        }
    }

    /// Lossless merge (exact samples, so no bucket-resolution loss).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Nearest-rank quantile over the recorded samples (0 if empty).
    pub fn quantile(&self, q: f64) -> f64 {
        let sorted = self.sorted();
        quantile_sorted(&sorted, q)
    }

    /// Summary statistics; one sort per call, so call once and reuse.
    pub fn summary(&self) -> HistSummary {
        let sorted = self.sorted();
        let n = sorted.len();
        if n == 0 {
            return HistSummary {
                count: 0,
                mean: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        HistSummary {
            count: n as u64,
            mean: sorted.iter().sum::<f64>() / n as f64,
            min: sorted[0],
            p50: quantile_sorted(&sorted, 0.50),
            p90: quantile_sorted(&sorted, 0.90),
            p99: quantile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// Compact log₂-bucket bar chart (microsecond-and-up buckets), for
    /// human-facing bench output.  Deterministic for a given sample set.
    pub fn ascii(&self, width: usize) -> String {
        let sorted = self.sorted();
        if sorted.is_empty() {
            return String::from("  (no samples)\n");
        }
        // bucket i covers [2^i, 2^(i+1)) microseconds; bucket 0 also
        // absorbs anything below 1us.
        let bucket_of = |s: f64| -> u32 {
            let us = s * 1e6;
            if us < 2.0 {
                0
            } else {
                us.log2().floor() as u32
            }
        };
        let lo = bucket_of(sorted[0]);
        let hi = bucket_of(sorted[sorted.len() - 1]);
        let mut counts = vec![0u64; (hi - lo + 1) as usize];
        for &s in &sorted {
            counts[(bucket_of(s) - lo) as usize] += 1;
        }
        let peak = counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in counts.iter().enumerate() {
            let lo_us = 1u64 << (lo as u64 + i as u64);
            let bar_w = ((c as f64 / peak as f64) * width as f64).round() as usize;
            let bar = "#".repeat(bar_w);
            out.push_str(&format!("  {:>9} | {:<w$} {}\n", fmt_us(lo_us), bar, c, w = width));
        }
        out
    }

    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        // samples are finite by construction (record() filters), so
        // total_cmp == partial order here; total_cmp keeps this
        // panic-free either way.
        s.sort_by(f64::total_cmp);
        s
    }
}

fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    // nearest-rank: the ceil(q*n)-th smallest sample (1-indexed)
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn fmt_us(us: u64) -> String {
    if us >= 1_000_000 {
        format!("{:.1}s", us as f64 / 1e6)
    } else if us >= 1_000 {
        format!("{:.1}ms", us as f64 / 1e3)
    } else {
        format!("{us}us")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_exact_order_statistics() {
        let mut h = LatencyHistogram::new();
        // 1..=100 ms, shuffled insertion order must not matter
        let mut vals: Vec<u64> = (1..=100).collect();
        vals.rotate_left(37);
        for v in vals {
            h.record(v as f64 * 1e-3);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.p50 - 0.050).abs() < 1e-12, "p50={}", s.p50);
        assert!((s.p90 - 0.090).abs() < 1e-12, "p90={}", s.p90);
        assert!((s.p99 - 0.099).abs() < 1e-12, "p99={}", s.p99);
        assert!((s.min - 0.001).abs() < 1e-12);
        assert!((s.max - 0.100).abs() < 1e-12);
        assert!((s.mean - 0.0505).abs() < 1e-12);
    }

    #[test]
    fn single_sample_summary() {
        let mut h = LatencyHistogram::new();
        h.record(0.25);
        let s = h.summary();
        assert_eq!(s.count, 1);
        for v in [s.mean, s.min, s.p50, s.p90, s.p99, s.max] {
            assert!((v - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_summary_is_zeroes_not_panics() {
        let s = LatencyHistogram::new().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0.0);
        assert!(LatencyHistogram::new().ascii(40).contains("no samples"));
    }

    #[test]
    fn merge_is_lossless() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 0..50 {
            a.record(i as f64 * 1e-3);
            b.record((i + 50) as f64 * 1e-3);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        let mut all = LatencyHistogram::new();
        for i in 0..100 {
            all.record(i as f64 * 1e-3);
        }
        assert_eq!(merged.summary(), all.summary());
    }

    #[test]
    fn non_finite_and_negative_samples_are_dropped() {
        let mut h = LatencyHistogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-1.0);
        h.record(0.5);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn ascii_renders_buckets() {
        let mut h = LatencyHistogram::new();
        for i in 1..=64 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 6.4ms
        }
        let art = h.ascii(30);
        assert!(art.contains('#'));
        assert!(art.lines().count() >= 2, "expect multiple buckets:\n{art}");
    }
}
