//! Length-prefixed binary frame I/O — the transport primitive under
//! `serve::net`'s `digest-wire-v1` protocol (and the codec seed for the
//! ROADMAP multi-process training transport).
//!
//! A frame on the wire is:
//!
//! ```text
//! u32 LE  length      # bytes that follow: 1 (opcode) + payload.len()
//! u8      opcode
//! [u8]    payload
//! ```
//!
//! The length prefix is capped ([`MAX_FRAME`] by default, callers can
//! tighten it) so a corrupt or hostile peer cannot make a reader
//! allocate unbounded memory.  All multi-byte primitives everywhere in
//! the codec are little-endian; floats travel as their IEEE-754 bit
//! patterns, so values round-trip bit-exactly — the same contract the
//! rest of the crate holds (checkpoints, fingerprints, predictions).
//!
//! [`ByteReader`] and the `put_*` helpers are the bounds-checked
//! primitive layer message codecs build on: every read is validated
//! against the remaining payload, strings carry a u32 length and must
//! be valid UTF-8, and [`ByteReader::finish`] rejects trailing bytes so
//! a decoded message is exactly its payload — nothing silently ignored.

use std::io::{ErrorKind, Read, Write};

use crate::{eyre, Result};

/// Default cap on the length prefix a reader will accept (64 MiB) —
/// comfortably above any real prediction frame (a full-graph reddit-m
/// response is ~20 MiB of logits) while bounding what a corrupt peer
/// can make us allocate.
pub const MAX_FRAME: u32 = 64 << 20;

/// Cap on an encoded string's length (names, error messages, paths).
pub const MAX_STR: usize = 1 << 16;

/// Outcome of one [`read_frame`] call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameRead {
    /// A complete frame: opcode + payload.
    Frame(u8, Vec<u8>),
    /// The peer closed the connection cleanly at a frame boundary.
    Closed,
    /// The socket's read timeout expired before the first byte of a
    /// frame arrived (only with a read timeout set); no bytes were
    /// consumed, so the stream is still at a frame boundary.
    TimedOut,
}

/// Write one frame and return the bytes put on the wire
/// (`4 + 1 + payload.len()`).  The frame is assembled into a single
/// buffer and written with one `write_all`, so a frame is never
/// interleaved mid-write with another writer's bytes on a duplicated
/// stream handle.
pub fn write_frame(w: &mut impl Write, opcode: u8, payload: &[u8]) -> Result<u64> {
    let body = payload.len() as u64 + 1;
    if body > MAX_FRAME as u64 {
        return Err(eyre!(
            "frame payload of {} bytes exceeds the {} byte cap",
            payload.len(),
            MAX_FRAME
        ));
    }
    let mut buf = Vec::with_capacity(5 + payload.len());
    buf.extend_from_slice(&(body as u32).to_le_bytes());
    buf.push(opcode);
    buf.extend_from_slice(payload);
    w.write_all(&buf)
        .map_err(|e| eyre!("writing {} byte frame: {e}", buf.len()))?;
    Ok(buf.len() as u64)
}

/// Read one frame, enforcing `max_len` on the length prefix.
///
/// Distinguishes a clean close (EOF before any length byte →
/// [`FrameRead::Closed`]) and a first-byte timeout ([`FrameRead::TimedOut`],
/// for sockets with a read timeout set) from mid-frame truncation,
/// oversized prefixes, and I/O errors, which are all hard `Err`s — once
/// a frame is partially consumed the stream can no longer be trusted to
/// be at a boundary.
pub fn read_frame(r: &mut impl Read, max_len: u32) -> Result<FrameRead> {
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(FrameRead::Closed),
            Ok(0) => return Err(eyre!("peer closed mid-frame ({got}/4 length bytes)")),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if got == 0
                    && matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) =>
            {
                return Ok(FrameRead::TimedOut);
            }
            Err(e) => return Err(eyre!("reading frame length: {e}")),
        }
    }
    let len = u32::from_le_bytes(len4);
    if len == 0 {
        return Err(eyre!("zero-length frame (missing opcode)"));
    }
    if len > max_len {
        return Err(eyre!("frame of {len} bytes exceeds the {max_len} byte cap"));
    }
    let mut body = vec![0u8; len as usize];
    read_exact_uninterrupted(r, &mut body)
        .map_err(|e| eyre!("reading {len} byte frame body: {e}"))?;
    let opcode = body[0];
    body.copy_within(1.., 0);
    body.truncate(len as usize - 1);
    Ok(FrameRead::Frame(opcode, body))
}

/// `read_exact` that retries `Interrupted` but treats a timeout
/// mid-body as the hard error it is (the stream has lost frame sync).
fn read_exact_uninterrupted(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<()> {
    let mut at = 0usize;
    while at < buf.len() {
        match r.read(&mut buf[at..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    format!("peer closed after {at}/{} body bytes", buf.len()),
                ))
            }
            Ok(n) => at += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

// ---- primitive encode helpers ------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// f32 as its IEEE-754 bit pattern (bit-exact round trip).
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// f64 as its IEEE-754 bit pattern (bit-exact round trip).
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// u32 length + UTF-8 bytes; errors above [`MAX_STR`].
pub fn put_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > MAX_STR {
        return Err(eyre!("string of {} bytes exceeds the {MAX_STR} byte cap", s.len()));
    }
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

// ---- bounds-checked payload reader -------------------------------------

/// Cursor over a message payload; every accessor validates against the
/// remaining bytes and returns a structured `Err` on truncation.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, at: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(eyre!(
                "truncated payload: {what} needs {n} bytes, {} remain",
                self.remaining()
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1, "u8")?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4, "u32")?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8, "u64")?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// u32 length + UTF-8 bytes, capped at [`MAX_STR`].
    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > MAX_STR {
            return Err(eyre!("string of {len} bytes exceeds the {MAX_STR} byte cap"));
        }
        let bytes = self.take(len, "string body")?;
        String::from_utf8(bytes.to_vec()).map_err(|e| eyre!("invalid UTF-8 string: {e}"))
    }

    /// Reject trailing bytes: a message must consume its payload exactly.
    pub fn finish(self) -> Result<()> {
        if self.at != self.buf.len() {
            return Err(eyre!(
                "{} trailing bytes after message body",
                self.buf.len() - self.at
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_round_trips_and_counts_bytes() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, 0x42, b"hello").unwrap();
        assert_eq!(n, 4 + 1 + 5);
        assert_eq!(buf.len() as u64, n);
        let mut c = Cursor::new(buf);
        match read_frame(&mut c, MAX_FRAME).unwrap() {
            FrameRead::Frame(op, payload) => {
                assert_eq!(op, 0x42);
                assert_eq!(payload, b"hello");
            }
            other => panic!("expected frame, got {other:?}"),
        }
        // stream is drained: next read is a clean close
        assert_eq!(read_frame(&mut c, MAX_FRAME).unwrap(), FrameRead::Closed);
    }

    #[test]
    fn empty_payload_frame_round_trips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"").unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(
            read_frame(&mut c, MAX_FRAME).unwrap(),
            FrameRead::Frame(7, Vec::new())
        );
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.push(1);
        let err = read_frame(&mut Cursor::new(buf), MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
        // and a tightened per-call cap applies too
        let mut small = Vec::new();
        write_frame(&mut small, 1, &[0u8; 100]).unwrap();
        let err = read_frame(&mut Cursor::new(small), 16).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn zero_length_frame_is_rejected() {
        let buf = 0u32.to_le_bytes().to_vec();
        let err = read_frame(&mut Cursor::new(buf), MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("zero-length"), "{err}");
    }

    #[test]
    fn truncation_mid_length_and_mid_body_are_errors() {
        let mut full = Vec::new();
        write_frame(&mut full, 9, b"abcdef").unwrap();
        // cut inside the length prefix
        let err = read_frame(&mut Cursor::new(&full[..2]), MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err}");
        // cut inside the body
        let err = read_frame(&mut Cursor::new(&full[..7]), MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("frame body"), "{err}");
    }

    #[test]
    fn oversized_write_is_refused() {
        // don't allocate 64 MiB in a unit test: a zero-copy reader over a
        // fake huge slice isn't possible, so check the boundary math via
        // the length check (payload.len() + 1 > MAX_FRAME).
        struct NullWriter;
        impl std::io::Write for NullWriter {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let big = vec![0u8; MAX_FRAME as usize];
        let err = write_frame(&mut NullWriter, 1, &big).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn byte_reader_round_trips_primitives_bit_exactly() {
        let mut out = Vec::new();
        put_u8(&mut out, 200);
        put_u32(&mut out, 0xDEADBEEF);
        put_u64(&mut out, u64::MAX - 1);
        put_f32(&mut out, -0.0);
        put_f32(&mut out, f32::NAN);
        put_f64(&mut out, 1.0 / 3.0);
        put_str(&mut out, "karate-gcn").unwrap();
        let mut r = ByteReader::new(&out);
        assert_eq!(r.u8().unwrap(), 200);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f32().unwrap().is_nan());
        assert_eq!(r.f64().unwrap().to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(r.str().unwrap(), "karate-gcn");
        r.finish().unwrap();
    }

    #[test]
    fn byte_reader_rejects_truncation_and_trailing_bytes() {
        let mut out = Vec::new();
        put_u32(&mut out, 5);
        let mut r = ByteReader::new(&out);
        r.u32().unwrap();
        assert!(r.u8().is_err(), "read past end must fail");

        let mut out = Vec::new();
        put_u32(&mut out, 1);
        put_u32(&mut out, 2);
        let mut r = ByteReader::new(&out);
        r.u32().unwrap();
        let err = r.finish().unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn string_caps_apply_both_ways() {
        let long = "x".repeat(MAX_STR + 1);
        assert!(put_str(&mut Vec::new(), &long).is_err());
        // decode side: a length prefix above the cap is refused before
        // any allocation
        let mut out = Vec::new();
        put_u32(&mut out, (MAX_STR + 1) as u32);
        assert!(ByteReader::new(&out).str().is_err());
    }

    #[test]
    fn non_utf8_string_is_a_structured_error() {
        let mut out = Vec::new();
        put_u32(&mut out, 2);
        out.extend_from_slice(&[0xFF, 0xFE]);
        let err = ByteReader::new(&out).str().unwrap_err();
        assert!(err.to_string().contains("UTF-8"), "{err}");
    }

    #[test]
    fn timeout_surfaces_only_at_frame_boundary() {
        struct TimeoutReader;
        impl Read for TimeoutReader {
            fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(ErrorKind::WouldBlock, "timed out"))
            }
        }
        assert_eq!(
            read_frame(&mut TimeoutReader, MAX_FRAME).unwrap(),
            FrameRead::TimedOut
        );
        // mid-length timeout is a hard error: one good byte, then block
        struct PartialThenBlock(usize);
        impl Read for PartialThenBlock {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.0 == 0 {
                    self.0 = 1;
                    buf[0] = 9;
                    Ok(1)
                } else {
                    Err(std::io::Error::new(ErrorKind::WouldBlock, "timed out"))
                }
            }
        }
        assert!(read_frame(&mut PartialThenBlock(0), MAX_FRAME).is_err());
    }
}
