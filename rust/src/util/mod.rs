//! Small shared utilities: a deterministic RNG and misc helpers.
//!
//! Every stochastic component in the library (graph generation, splits,
//! partitioner tie-breaking, straggler injection, parameter init) takes
//! an explicit seed so whole experiments are bit-reproducible.

/// xoshiro256++ — fast, high-quality, dependency-free deterministic RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a good seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Derive an independent child RNG (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Export the raw generator state (training-state checkpoints).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from an exported state: the restored stream
    /// continues exactly where [`Rng::state`] captured it.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

/// Lock a mutex, recovering the guard when a panicking thread poisoned
/// it.  The shared stores guarded this way (KVS shards, PS state,
/// runtime caches) hold plain data that is structurally valid after any
/// partial update, so the poison flag carries no information here — and
/// honoring it would cascade one crashed worker's panic into every
/// other worker's `.lock().unwrap()`.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Derive a domain-separated seed: components seeded from the same user
/// seed must not share RNG streams (a shared stream once made the
/// "random" partitioner exactly reproduce the SBM community shuffle —
/// a perfectly community-aligned "random" baseline).
pub fn domain_seed(seed: u64, domain: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64; // FNV-1a
    for b in domain.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    seed ^ h
}

/// Incremental 64-bit content hash (FNV-1a style word mixer) used for
/// dataset/graph fingerprints: a [`crate::serve::InferenceModel`]
/// records the fingerprint of the graph it was trained on so a serving
/// engine can refuse to apply it to a different graph with a structured
/// error instead of producing silently-wrong predictions.  Mixing whole
/// 64-bit words (rather than canonical byte-at-a-time FNV) keeps
/// fingerprinting a 100k-node feature matrix in the tens of
/// milliseconds; this is a content identity, not a cryptographic hash.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }

    #[inline]
    pub fn mix(&mut self, v: u64) {
        self.0 = (self.0 ^ v).wrapping_mul(0x100000001b3);
    }

    #[inline]
    pub fn mix_f32(&mut self, v: f32) {
        // bit pattern, not value: -0.0 and 0.0 fingerprint differently,
        // matching the crate's bit-exactness contracts elsewhere
        self.mix(v.to_bits() as u64);
    }

    pub fn finish(&self) -> u64 {
        // final avalanche (SplitMix64 finalizer) so short inputs still
        // spread across all 64 bits
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Write a file atomically: write and fsync a same-directory temp
/// file, then rename it over the target.  Readers polling the path —
/// a serving registry hot-reloading the model file the training-side
/// export hook keeps overwriting, or a resume loading a checkpoint
/// mid-save — never observe a truncated or half-written file; the
/// `sync_all` before the rename keeps that true across a power loss
/// too (without it, journaling filesystems can commit the rename
/// before the data blocks).  The parent directory is not fsynced: a
/// crash can at worst revert to the previous complete file, never
/// expose a partial one.
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> crate::Result<()> {
    use std::io::Write as _;
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    let mut f =
        std::fs::File::create(&tmp).map_err(|e| crate::eyre!("creating {tmp:?}: {e}"))?;
    f.write_all(bytes)
        .and_then(|_| f.sync_all())
        .map_err(|e| crate::eyre!("writing {tmp:?}: {e}"))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .map_err(|e| crate::eyre!("renaming {tmp:?} over {path:?}: {e}"))
}

/// Format a byte count human-readably (metrics/telemetry output).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Mean of an f64 slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal() as f64).collect();
        assert!(mean(&xs).abs() < 0.05);
        assert!((stddev(&xs) - 1.0).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn lock_unpoisoned_recovers_poisoned_mutex() {
        use std::sync::{Arc, Mutex};
        let m = Arc::new(Mutex::new(7));
        let m2 = m.clone();
        let h = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        });
        assert!(h.join().is_err());
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7);
        // and the guard still works for writes afterwards
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }

    #[test]
    fn fnv64_is_deterministic_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.mix(1);
        a.mix(2);
        let mut b = Fnv64::new();
        b.mix(1);
        b.mix(2);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.mix(2);
        c.mix(1);
        assert_ne!(a.finish(), c.finish(), "order must matter");
        // sign of a float zero is content
        let mut z0 = Fnv64::new();
        z0.mix_f32(0.0);
        let mut z1 = Fnv64::new();
        z1.mix_f32(-0.0);
        assert_ne!(z0.finish(), z1.finish());
    }

    #[test]
    fn write_atomic_replaces_and_renames_the_tmp_away() {
        let path = std::env::temp_dir().join("digest_util_atomic.txt");
        write_atomic(&path, b"one").unwrap();
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        let tmp = std::path::PathBuf::from(format!("{}.tmp", path.display()));
        assert!(!tmp.exists(), "tmp file must be renamed over the target");
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn rng_state_round_trip_continues_stream() {
        let mut a = Rng::new(1234);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}

pub mod frame;
pub mod hist;
pub mod json;
pub mod prop;

#[cfg(test)]
mod seed_tests {
    use super::*;

    #[test]
    fn domain_seeds_differ_per_domain() {
        let a = domain_seed(42, "partition");
        let b = domain_seed(42, "dataset");
        assert_ne!(a, b);
        assert_ne!(a, 42);
        // deterministic
        assert_eq!(a, domain_seed(42, "partition"));
    }
}
