//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator hot path.  Python is never involved at runtime.
//!
//! Pipeline (see /opt/xla-example/load_hlo for the reference wiring):
//!
//! ```text
//! HLO text --HloModuleProto::from_text_file--> proto
//!          --XlaComputation::from_proto------> computation
//!          --PjRtClient::compile-------------> loaded executable (cached)
//!          --execute(literals)---------------> output tuple literals
//! ```
//!
//! HLO **text** (not serialized proto) is the interchange format because
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

pub mod manifest;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

use crate::halo::SubgraphPlan;
use crate::tensor::sparse::CsrMatrix;
use crate::tensor::Matrix;
use crate::util::{lock_unpoisoned, Rng};
use crate::{eyre, Result};

// ---------------------------------------------------------------------------
// Thread-safety wrappers
// ---------------------------------------------------------------------------
//
// The `xla` binding wraps raw C pointers and conservatively leaves its
// types `!Send + !Sync`.  The underlying PJRT contracts are stronger:
// the C API documents `PJRT_LoadedExecutable_Execute` as thread-safe
// (the CPU client dispatches concurrent executions onto its own thread
// pool), and a packed `Literal` is an immutable host buffer after
// construction — executions only *read* it while copying it into device
// buffers.  The wrappers below encode exactly those two facts so the
// coordinator can run real worker threads; everything that mutates
// (executable cache, stats) stays behind mutexes.

/// A compiled PJRT executable shared across worker threads.
///
/// Safety: `PJRT_LoadedExecutable_Execute` is thread-safe per the PJRT C
/// API contract; the handle itself is immutable after compilation.
pub struct SharedExecutable(xla::PjRtLoadedExecutable);

// SAFETY: `PJRT_LoadedExecutable_Execute` is thread-safe per the PJRT C
// API contract, and the handle is immutable after compilation — no
// unsynchronized interior mutability crosses threads.
unsafe impl Send for SharedExecutable {}
unsafe impl Sync for SharedExecutable {}

/// A packed input literal that worker threads may read concurrently.
///
/// Safety: a `Literal` is written only during packing (before it is
/// shared); every later use is a read of the host buffer.
pub struct SharedLiteral(xla::Literal);

// SAFETY: the literal's host buffer is written only during packing,
// strictly before it is shared; every cross-thread use afterwards is a
// read, so concurrent access is data-race free.
unsafe impl Send for SharedLiteral {}
unsafe impl Sync for SharedLiteral {}

impl std::ops::Deref for SharedLiteral {
    type Target = xla::Literal;
    fn deref(&self) -> &xla::Literal {
        &self.0
    }
}

impl From<xla::Literal> for SharedLiteral {
    fn from(lit: xla::Literal) -> Self {
        SharedLiteral(lit)
    }
}

/// Owns the PJRT client, the manifest, and the compiled-executable cache.
///
/// `Runtime` is `Sync`: `execute` may be called from many worker threads
/// at once (see [`SharedExecutable`] for the safety argument), which is
/// what lets the coordinator run M workers truly in parallel instead of
/// simulating parallelism on the virtual clock alone.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<HashMap<(String, String), Arc<SharedExecutable>>>,
    /// Monotonic counters for profiling.
    pub stats: Mutex<RuntimeStats>,
}

// SAFETY: `client` compiles under the `exes` mutex (PjRtClient::compile
// is additionally documented thread-safe in PJRT); all interior
// mutability is mutex-guarded; executables and literals cross threads
// only via the wrappers above.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub execute_seconds: f64,
    pub pack_seconds: f64,
}

impl Runtime {
    pub fn new(artifact_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| eyre!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Compile (or fetch cached) the executable for (name, kind).
    /// Compilation happens under the cache lock so concurrent workers
    /// racing on a cold cache compile each artifact exactly once.
    pub fn load(&self, name: &str, kind: &str) -> Result<Arc<SharedExecutable>> {
        let key = (name.to_string(), kind.to_string());
        let mut exes = lock_unpoisoned(&self.exes);
        if let Some(exe) = exes.get(&key) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name, kind)?;
        let path = self.manifest.hlo_path(spec);
        let path_str = path
            .to_str()
            .ok_or_else(|| eyre!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| eyre!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| eyre!("compiling {name}/{kind}: {e}"))?;
        lock_unpoisoned(&self.stats).compiles += 1;
        let rc = Arc::new(SharedExecutable(exe));
        exes.insert(key, rc.clone());
        Ok(rc)
    }

    /// Execute artifact (name, kind) with packed input literals; returns
    /// the decomposed output tuple.  Accepts owned literals or
    /// references (the cached hot path passes `&[&Literal]`).
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        kind: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name, kind)?;
        let t0 = std::time::Instant::now();
        let result = exe
            .0
            .execute::<L>(inputs)
            .map_err(|e| eyre!("executing {name}/{kind}: {e}"))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| eyre!("fetching result of {name}/{kind}: {e}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| eyre!("decomposing result tuple: {e}"))?;
        let mut stats = lock_unpoisoned(&self.stats);
        stats.executions += 1;
        stats.execute_seconds += t0.elapsed().as_secs_f64();
        Ok(parts)
    }

    pub fn stats(&self) -> RuntimeStats {
        *lock_unpoisoned(&self.stats)
    }
}

// ---------------------------------------------------------------------------
// Literal packing / unpacking
// ---------------------------------------------------------------------------

/// Pack an f32 matrix as a literal with the spec's shape.
pub fn pack_matrix(spec: &TensorSpec, m: &Matrix) -> Result<xla::Literal> {
    if spec.dtype != DType::F32 {
        return Err(eyre!("{}: expected f32", spec.name));
    }
    if m.data.len() != spec.elements() {
        return Err(eyre!(
            "{}: have {} elements, spec wants {:?}",
            spec.name,
            m.data.len(),
            spec.shape
        ));
    }
    // 2-D specs demand an exact shape match: equal element count alone
    // once let a (1,6) pass against a (2,3) spec and silently reshape.
    if spec.shape.len() == 2 && !(m.rows == spec.shape[0] && m.cols == spec.shape[1]) {
        return Err(eyre!(
            "{}: matrix {}x{} vs spec {:?}",
            spec.name,
            m.rows,
            m.cols,
            spec.shape
        ));
    }
    // 1-D specs accept only the unambiguous (1, n) <-> (n,) flatten.
    if spec.shape.len() == 1 && m.rows != 1 {
        return Err(eyre!(
            "{}: matrix {}x{} vs 1-D spec {:?} (only (1, n) flattens)",
            spec.name,
            m.rows,
            m.cols,
            spec.shape
        ));
    }
    let lit = xla::Literal::vec1(&m.data);
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| eyre!("reshape {}: {e}", spec.name))
}

/// Densify a sparse plan matrix (p_in / p_out) straight into a literal
/// with the spec's 2-D shape — the only point where propagation
/// matrices go dense.  Scattering writes each stored entry into its
/// slot of a zero buffer, so the packed bytes are identical to packing
/// the seed's dense construction.
pub fn pack_csr(spec: &TensorSpec, m: &CsrMatrix) -> Result<xla::Literal> {
    if spec.dtype != DType::F32 {
        return Err(eyre!("{}: expected f32", spec.name));
    }
    if spec.shape.len() != 2 || spec.shape[0] != m.rows || spec.shape[1] != m.cols {
        return Err(eyre!(
            "{}: csr {}x{} vs spec {:?}",
            spec.name,
            m.rows,
            m.cols,
            spec.shape
        ));
    }
    let mut flat = vec![0f32; m.rows * m.cols];
    m.scatter_into(&mut flat);
    let lit = xla::Literal::vec1(&flat);
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| eyre!("reshape {}: {e}", spec.name))
}

/// Pack an f32 slice (1-D specs).
pub fn pack_f32(spec: &TensorSpec, v: &[f32]) -> Result<xla::Literal> {
    if v.len() != spec.elements() {
        return Err(eyre!("{}: {} vs {:?}", spec.name, v.len(), spec.shape));
    }
    Ok(xla::Literal::vec1(v))
}

/// Pack an i32 slice.
pub fn pack_i32(spec: &TensorSpec, v: &[i32]) -> Result<xla::Literal> {
    if spec.dtype != DType::I32 || v.len() != spec.elements() {
        return Err(eyre!("{}: bad i32 pack", spec.name));
    }
    Ok(xla::Literal::vec1(v))
}

/// Unpack a literal into a Matrix using the spec's (2-D or 1-D) shape.
pub fn unpack_matrix(spec: &TensorSpec, lit: &xla::Literal) -> Result<Matrix> {
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| eyre!("unpack {}: {e}", spec.name))?;
    let (rows, cols) = match spec.shape.len() {
        2 => (spec.shape[0], spec.shape[1]),
        1 => (1, spec.shape[0]),
        0 => (1, 1),
        _ => return Err(eyre!("{}: rank > 2 unsupported", spec.name)),
    };
    if data.len() != rows * cols {
        return Err(eyre!("{}: got {} elements", spec.name, data.len()));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

fn unpack_scalar(spec: &TensorSpec, lit: &xla::Literal) -> Result<f32> {
    let v = lit
        .to_vec::<f32>()
        .map_err(|e| eyre!("unpack {}: {e}", spec.name))?;
    v.first()
        .copied()
        .ok_or_else(|| eyre!("{}: empty scalar", spec.name))
}

// ---------------------------------------------------------------------------
// Step-level IO
// ---------------------------------------------------------------------------

/// Parsed outputs of one train-step execution.
#[derive(Debug, Clone)]
pub struct TrainOutput {
    pub loss: f32,
    pub ncorrect: f32,
    pub logits: Matrix,
    /// Fresh per-layer hidden representations (S_pad rows each).
    pub reps: Vec<Matrix>,
    /// Gradients in manifest parameter order.
    pub grads: Vec<Matrix>,
}

/// Parsed outputs of one eval-step execution.
#[derive(Debug, Clone)]
pub struct EvalOutput {
    pub logits: Matrix,
    pub reps: Vec<Matrix>,
}

/// Pack the full train/eval input list for one subgraph step.
///
/// Order (the manifest contract): x, p_in, p_out, h_stale_0..L-2,
/// per-layer params, y, mask.
pub fn pack_step_inputs(
    spec: &ArtifactSpec,
    plan: &SubgraphPlan,
    stale: &[Matrix],
    params: &[Matrix],
    mask: &[f32],
) -> Result<Vec<xla::Literal>> {
    if stale.len() != spec.layers - 1 {
        return Err(eyre!(
            "need {} stale tensors, got {}",
            spec.layers - 1,
            stale.len()
        ));
    }
    if params.len() != spec.n_params() {
        return Err(eyre!(
            "need {} param tensors, got {}",
            spec.n_params(),
            params.len()
        ));
    }
    let mut lits = Vec::with_capacity(spec.inputs.len());
    let mut idx = 0usize;
    lits.push(pack_matrix(&spec.inputs[idx], &plan.x)?);
    idx += 1;
    lits.push(pack_csr(&spec.inputs[idx], &plan.p_in)?);
    idx += 1;
    lits.push(pack_csr(&spec.inputs[idx], &plan.p_out)?);
    idx += 1;
    for s in stale {
        lits.push(pack_matrix(&spec.inputs[idx], s)?);
        idx += 1;
    }
    for p in params {
        lits.push(pack_matrix(&spec.inputs[idx], p)?);
        idx += 1;
    }
    // eval artifacts end after the params (y/mask are train-only: unused
    // entry parameters would be DCE'd by XLA)
    if spec.kind == "train" {
        lits.push(pack_i32(&spec.inputs[idx], &plan.y)?);
        idx += 1;
        lits.push(pack_f32(&spec.inputs[idx], mask)?);
        idx += 1;
    }
    if idx != spec.inputs.len() {
        return Err(eyre!(
            "packed {idx} inputs, manifest expects {}",
            spec.inputs.len()
        ));
    }
    Ok(lits)
}

/// Parse a train-step output tuple.
pub fn parse_train_output(spec: &ArtifactSpec, outs: &[xla::Literal]) -> Result<TrainOutput> {
    if outs.len() != spec.outputs.len() {
        return Err(eyre!(
            "train output arity {} != manifest {}",
            outs.len(),
            spec.outputs.len()
        ));
    }
    let loss = unpack_scalar(&spec.outputs[0], &outs[0])?;
    let ncorrect = unpack_scalar(&spec.outputs[1], &outs[1])?;
    let logits = unpack_matrix(&spec.outputs[2], &outs[2])?;
    let n_reps = spec.layers - 1;
    let off = spec.rep_output_offset();
    let reps = (0..n_reps)
        .map(|i| unpack_matrix(&spec.outputs[off + i], &outs[off + i]))
        .collect::<Result<Vec<_>>>()?;
    let goff = off + n_reps;
    let grads = (goff..spec.outputs.len())
        .map(|i| unpack_matrix(&spec.outputs[i], &outs[i]))
        .collect::<Result<Vec<_>>>()?;
    if grads.len() != spec.n_params() {
        return Err(eyre!("grad arity {} != {}", grads.len(), spec.n_params()));
    }
    Ok(TrainOutput {
        loss,
        ncorrect,
        logits,
        reps,
        grads,
    })
}

/// Parse an eval-step output tuple.
pub fn parse_eval_output(spec: &ArtifactSpec, outs: &[xla::Literal]) -> Result<EvalOutput> {
    if outs.len() != spec.outputs.len() {
        return Err(eyre!(
            "eval output arity {} != manifest {}",
            outs.len(),
            spec.outputs.len()
        ));
    }
    let logits = unpack_matrix(&spec.outputs[0], &outs[0])?;
    let reps = (1..spec.outputs.len())
        .map(|i| unpack_matrix(&spec.outputs[i], &outs[i]))
        .collect::<Result<Vec<_>>>()?;
    Ok(EvalOutput { logits, reps })
}

// ---------------------------------------------------------------------------
// Cached-literal hot path (§Perf optimization)
// ---------------------------------------------------------------------------
//
// A subgraph's x, p_in, p_out, y and mask never change across epochs, and
// its stale tensors change only on sync epochs — but the naive path
// re-marshals all of them into fresh literals every step (the x matrix
// alone is ~1 MB for arxiv-scale configs).  The cached path packs the
// static inputs once per worker, the stale inputs once per pull, and the
// parameters once per PS fetch (shared by all M workers), then assembles
// a borrow-only argument list per execution.

/// Statically-packed per-plan input literals, shareable across the
/// worker threads that execute against them.
pub struct StaticInputs {
    pub x: SharedLiteral,
    pub p_in: SharedLiteral,
    pub p_out: SharedLiteral,
    pub y: SharedLiteral,
    pub mask: SharedLiteral,
}

/// Pack the inputs of `plan` that never change across epochs.
/// `mask` selects which split trains (usually the train mask).
pub fn pack_static_inputs(
    spec: &ArtifactSpec,
    plan: &SubgraphPlan,
    mask: &[f32],
) -> Result<StaticInputs> {
    let n_inputs = spec.inputs.len();
    Ok(StaticInputs {
        x: pack_matrix(&spec.inputs[0], &plan.x)?.into(),
        p_in: pack_csr(&spec.inputs[1], &plan.p_in)?.into(),
        p_out: pack_csr(&spec.inputs[2], &plan.p_out)?.into(),
        y: pack_i32(&spec.inputs[n_inputs - 2], &plan.y)?.into(),
        mask: pack_f32(&spec.inputs[n_inputs - 1], mask)?.into(),
    })
}

/// Pack one hidden layer's stale tensor.  Per-layer granularity is the
/// point: a periodic sync that leaves a layer's halo rows untouched
/// reuses the layer's existing `Arc` instead of re-marshalling it
/// (dirty-layer tracking in `coordinator::worker::pull_stale`).
pub fn pack_stale_layer(
    spec: &ArtifactSpec,
    layer: usize,
    stale: &Matrix,
) -> Result<Arc<SharedLiteral>> {
    if layer >= spec.layers - 1 {
        return Err(eyre!(
            "stale layer {layer} out of range (layers = {})",
            spec.layers
        ));
    }
    Ok(Arc::new(pack_matrix(&spec.inputs[3 + layer], stale)?.into()))
}

/// Pack the L-1 stale tensors (done once per KVS pull, not per step;
/// the dirty-layer path repacks individual layers via
/// [`pack_stale_layer`]).
pub fn pack_stale(spec: &ArtifactSpec, stale: &[Matrix]) -> Result<Vec<Arc<SharedLiteral>>> {
    if stale.len() != spec.layers - 1 {
        return Err(eyre!("need {} stale tensors", spec.layers - 1));
    }
    stale
        .iter()
        .enumerate()
        .map(|(l, s)| pack_stale_layer(spec, l, s))
        .collect()
}

/// Pack the parameter tensors (done once per PS fetch, shared by all
/// workers in the epoch — and, with the parallel engine, by all worker
/// *threads* concurrently).
pub fn pack_params(spec: &ArtifactSpec, params: &[Matrix]) -> Result<Vec<SharedLiteral>> {
    if params.len() != spec.n_params() {
        return Err(eyre!("need {} param tensors", spec.n_params()));
    }
    let off = spec.param_input_offset();
    params
        .iter()
        .enumerate()
        .map(|(i, p)| pack_matrix(&spec.inputs[off + i], p).map(Into::into))
        .collect()
}

/// Assemble the borrow-only argument list for a step execution.
/// `kind` decides whether the trailing y/mask are included (train only).
/// Stale literals arrive as per-layer `Arc`s (the dirty-layer sync path
/// shares untouched layers across pulls).
pub fn assemble_inputs<'a>(
    spec: &ArtifactSpec,
    statics: &'a StaticInputs,
    stale: &'a [Arc<SharedLiteral>],
    params: &'a [SharedLiteral],
) -> Vec<&'a xla::Literal> {
    let mut v = Vec::with_capacity(spec.inputs.len());
    v.push(&*statics.x);
    v.push(&*statics.p_in);
    v.push(&*statics.p_out);
    v.extend(stale.iter().map(|l| &***l));
    v.extend(params.iter().map(|l| &**l));
    if spec.kind == "train" {
        v.push(&*statics.y);
        v.push(&*statics.mask);
    }
    debug_assert_eq!(v.len(), spec.inputs.len());
    v
}

/// Initialize parameters matching the artifact spec (same distribution
/// as `python/compile/models`: Glorot-uniform W, zero b, 0.1·N(0,1)
/// attention vectors).  Deterministic in `seed`.
pub fn init_params(spec: &ArtifactSpec, seed: u64) -> Vec<Matrix> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(spec.n_params());
    let off = spec.param_input_offset();
    for t in &spec.inputs[off..off + spec.n_params()] {
        let m = if t.name.ends_with("_w") {
            Matrix::glorot(t.shape[0], t.shape[1], &mut rng)
        } else if t.name.ends_with("_b") {
            Matrix::zeros(1, t.shape[0])
        } else {
            // a_src / a_dst
            Matrix::from_fn(1, t.shape[0], |_, _| 0.1 * rng.normal())
        };
        out.push(m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec1(name: &str, shape: Vec<usize>, dtype: DType) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape,
            dtype,
        }
    }

    #[test]
    fn pack_matrix_validates_shape() {
        let spec = spec1("t", vec![2, 3], DType::F32);
        assert!(pack_matrix(&spec, &Matrix::zeros(2, 3)).is_ok());
        assert!(pack_matrix(&spec, &Matrix::zeros(3, 2)).is_err());
        assert!(pack_matrix(&spec, &Matrix::zeros(2, 2)).is_err());
        // regression: equal element count must NOT pass a 2-D spec with
        // a different shape (a (1,6) was silently reshaped to (2,3))
        assert!(pack_matrix(&spec, &Matrix::zeros(1, 6)).is_err());
        assert!(pack_matrix(&spec, &Matrix::zeros(6, 1)).is_err());
        // (1, n) flattens into (n,) specs — the only allowed reshape
        let vecspec = spec1("b", vec![6], DType::F32);
        assert!(pack_matrix(&vecspec, &Matrix::zeros(1, 6)).is_ok());
        assert!(pack_matrix(&vecspec, &Matrix::zeros(6, 1)).is_err());
        assert!(pack_matrix(&vecspec, &Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn pack_csr_matches_dense_packing() {
        use crate::tensor::sparse::CsrBuilder;
        let spec = spec1("p", vec![3, 4], DType::F32);
        let mut b = CsrBuilder::new(3, 4);
        b.push(1, 0.5);
        b.push(3, -2.0);
        b.finish_row();
        b.finish_row();
        b.push(0, 1.25);
        b.finish_row();
        let csr = b.finish();
        let lit = pack_csr(&spec, &csr).unwrap();
        let dense_lit = pack_matrix(&spec, &csr.to_dense()).unwrap();
        assert_eq!(
            lit.to_vec::<f32>().unwrap(),
            dense_lit.to_vec::<f32>().unwrap()
        );
        // shape must match the spec exactly
        let bad = spec1("p", vec![4, 3], DType::F32);
        assert!(pack_csr(&bad, &csr).is_err());
        let one_d = spec1("p", vec![12], DType::F32);
        assert!(pack_csr(&one_d, &csr).is_err());
    }

    #[test]
    fn pack_unpack_round_trip() {
        let spec = spec1("t", vec![3, 4], DType::F32);
        let m = Matrix::from_fn(3, 4, |r, c| (r * 4 + c) as f32);
        let lit = pack_matrix(&spec, &m).unwrap();
        let back = unpack_matrix(&spec, &lit).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn pack_i32_and_f32_validate_lengths() {
        let yspec = spec1("y", vec![4], DType::I32);
        assert!(pack_i32(&yspec, &[1, 2, 3, 4]).is_ok());
        assert!(pack_i32(&yspec, &[1, 2]).is_err());
        let mspec = spec1("mask", vec![4], DType::F32);
        assert!(pack_f32(&mspec, &[1.0; 4]).is_ok());
        assert!(pack_f32(&mspec, &[1.0; 5]).is_err());
    }

    #[test]
    fn init_params_matches_manifest_spec() {
        let m = Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).unwrap();
        for (name, kind) in [("karate_gcn", "train"), ("karate_gat", "train")] {
            let spec = m.get(name, kind).unwrap();
            let params = init_params(spec, 7);
            assert_eq!(params.len(), spec.n_params());
            let off = spec.param_input_offset();
            for (p, t) in params.iter().zip(&spec.inputs[off..]) {
                assert_eq!(p.data.len(), t.elements(), "{}", t.name);
            }
            // deterministic
            let again = init_params(spec, 7);
            assert_eq!(params[0].data, again[0].data);
            // w is non-zero, b zero
            assert!(params[0].frobenius_norm() > 0.0);
            assert_eq!(params[1].frobenius_norm(), 0.0);
        }
    }
}
