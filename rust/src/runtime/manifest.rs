//! Artifact manifest: the ABI contract emitted by `python/compile/aot.py`.
//!
//! `manifest.json` describes every AOT artifact: file name, model kind,
//! padded shapes, and the exact positional input/output tensor lists the
//! HLO entry computation expects.  The Rust side packs literals in this
//! order and never guesses.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{eyre, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => Err(eyre!("unknown dtype {s:?}")),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One named tensor in the artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
        })
    }
}

/// One AOT artifact (a train or eval step for one config).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String, // "train" | "eval"
    pub model: String, // "gcn" | "gat"
    pub file: String,
    pub layers: usize,
    pub s_pad: usize,
    pub b_pad: usize,
    pub d_in: usize,
    pub d_h: usize,
    pub n_class: usize,
    pub act: String,
    pub normalize: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ArtifactSpec {
            name: j.get("name")?.as_str()?.to_string(),
            kind: j.get("kind")?.as_str()?.to_string(),
            model: j.get("model")?.as_str()?.to_string(),
            file: j.get("file")?.as_str()?.to_string(),
            layers: j.get("layers")?.as_usize()?,
            s_pad: j.get("s_pad")?.as_usize()?,
            b_pad: j.get("b_pad")?.as_usize()?,
            d_in: j.get("d_in")?.as_usize()?,
            d_h: j.get("d_h")?.as_usize()?,
            n_class: j.get("n_class")?.as_usize()?,
            act: j.get("act")?.as_str()?.to_string(),
            normalize: j.get("normalize")?.as_bool()?,
            inputs: j
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: j
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
        })
    }

    /// The crate-wide [`crate::gnn::ModelKind`] this artifact was built
    /// for (the manifest stores it as a string; `serve` export
    /// validates through this instead of re-parsing ad hoc).
    pub fn model_kind(&self) -> crate::Result<crate::gnn::ModelKind> {
        self.model.parse()
    }

    /// GNN layer dims [d_in, d_h, ..., n_class].
    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.d_in];
        d.extend(std::iter::repeat(self.d_h).take(self.layers - 1));
        d.push(self.n_class);
        d
    }

    /// Index of the first parameter tensor in `inputs`
    /// (after x, p_in, p_out, and the L-1 stale tensors).
    pub fn param_input_offset(&self) -> usize {
        3 + (self.layers - 1)
    }

    /// Number of parameter tensors.
    pub fn n_params(&self) -> usize {
        let ppl = match self.model.as_str() {
            "gat" => 4,
            "sage" => 3,
            _ => 2,
        };
        self.layers * ppl
    }

    /// Output index of the first fresh-representation tensor.
    pub fn rep_output_offset(&self) -> usize {
        match self.kind.as_str() {
            "train" => 3, // loss, ncorrect, logits
            _ => 1,       // logits
        }
    }

    /// Total input bytes (the per-step H2D traffic).
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(|t| t.elements() * 4).sum()
    }
}

/// The parsed manifest, keyed by (name, kind).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<(String, String), ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| eyre!("reading {path:?}: {e}; run `make artifacts` first"))?;
        let j = Json::parse(&text)?;
        let mut artifacts = HashMap::new();
        for a in j.get("artifacts")?.as_arr()? {
            let spec = ArtifactSpec::from_json(a)?;
            artifacts.insert((spec.name.clone(), spec.kind.clone()), spec);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str, kind: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(&(name.to_string(), kind.to_string()))
            .ok_or_else(|| {
                eyre!(
                    "artifact {name}/{kind} not in manifest ({} entries)",
                    self.artifacts.len()
                )
            })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn parses_real_manifest() {
        let m = Manifest::load(manifest_dir()).expect("run `make artifacts` first");
        let spec = m.get("karate_gcn", "train").unwrap();
        assert_eq!(spec.layers, 2);
        assert_eq!(spec.s_pad, 32);
        assert_eq!(spec.model, "gcn");
        // input order contract
        assert_eq!(spec.inputs[0].name, "x");
        assert_eq!(spec.inputs[1].name, "p_in");
        assert_eq!(spec.inputs[2].name, "p_out");
        assert_eq!(spec.inputs[3].name, "h_stale_0");
        assert_eq!(spec.inputs[4].name, "l0_w");
        assert_eq!(spec.inputs.last().unwrap().name, "mask");
        assert_eq!(spec.inputs.last().unwrap().dtype, DType::F32);
        // y is i32
        let y = spec.inputs.iter().find(|t| t.name == "y").unwrap();
        assert_eq!(y.dtype, DType::I32);
        // outputs
        assert_eq!(spec.outputs[0].name, "loss");
        assert_eq!(spec.outputs[2].name, "logits");
        assert_eq!(spec.rep_output_offset(), 3);
        assert_eq!(spec.param_input_offset(), 4);
        assert_eq!(spec.n_params(), 4);
        assert_eq!(spec.dims(), vec![16, 16, 4]);
    }

    #[test]
    fn gat_artifact_has_attention_params() {
        let m = Manifest::load(manifest_dir()).unwrap();
        let spec = m.get("karate_gat", "train").unwrap();
        assert_eq!(spec.model_kind().unwrap(), crate::gnn::ModelKind::Gat);
        assert_eq!(spec.n_params(), 8);
        assert_eq!(spec.inputs[4].name, "l0_w");
        assert_eq!(spec.inputs[6].name, "l0_a_src");
    }

    #[test]
    fn eval_artifacts_present() {
        let m = Manifest::load(manifest_dir()).unwrap();
        let spec = m.get("karate_gcn", "eval").unwrap();
        assert_eq!(spec.outputs[0].name, "logits");
        assert_eq!(spec.rep_output_offset(), 1);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::load(manifest_dir()).unwrap();
        assert!(m.get("nope", "train").is_err());
    }

    #[test]
    fn l3_artifact_has_two_stale_inputs() {
        let m = Manifest::load(manifest_dir()).unwrap();
        let spec = m.get("arxiv_s_l3_gcn", "train").unwrap();
        assert_eq!(spec.layers, 3);
        assert_eq!(spec.inputs[3].name, "h_stale_0");
        assert_eq!(spec.inputs[4].name, "h_stale_1");
        assert_eq!(spec.param_input_offset(), 5);
        assert_eq!(spec.dims(), vec![128, 64, 64, 40]);
    }
}
