//! Graph substrate: CSR graphs, node-classification datasets, generators.
//!
//! The paper evaluates on OGB-Arxiv / Flickr / Reddit / OGB-Products.
//! Those cannot be downloaded here, so [`registry`] provides synthetic
//! stochastic-block-model stand-ins matched in relative density, feature
//! dimension and class count (DESIGN.md §2), plus the real Zachary
//! karate-club graph for sanity tests.

pub mod generators;
pub mod io;
pub mod karate;
pub mod registry;
pub mod splits;
pub mod stats;

use crate::tensor::Matrix;

/// Undirected graph in CSR form.  Edges are stored in both directions;
/// no self-loops (GCN normalization adds them).
#[derive(Debug, Clone)]
pub struct Graph {
    /// Row offsets, length n+1.
    pub offsets: Vec<usize>,
    /// Column indices (neighbor ids), length 2|E|.
    pub targets: Vec<u32>,
}

impl Graph {
    /// Build from an undirected edge list (u, v); duplicates and
    /// self-loops are dropped.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            let (u, v) = (u as usize, v as usize);
            assert!(u < n && v < n, "edge ({u},{v}) out of range n={n}");
            if u == v {
                continue;
            }
            adj[u].push(v as u32);
            adj[v].push(u as u32);
        }
        for l in &mut adj {
            l.sort_unstable();
            l.dedup();
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut targets = Vec::new();
        offsets.push(0);
        for l in &adj {
            targets.extend_from_slice(l);
            offsets.push(targets.len());
        }
        Graph { offsets, targets }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    pub fn m(&self) -> usize {
        self.targets.len() / 2
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.targets[self.offsets[v]..self.offsets[v + 1]]
    }

    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        self.targets.len() as f64 / self.n() as f64
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// GCN symmetric normalization weight for edge (u, v) including
    /// self-loops: 1 / sqrt((d_u + 1)(d_v + 1)).
    #[inline]
    pub fn norm_weight(&self, u: usize, v: usize) -> f32 {
        let du = (self.degree(u) + 1) as f32;
        let dv = (self.degree(v) + 1) as f32;
        1.0 / (du * dv).sqrt()
    }

    /// Content fingerprint of the graph structure (node count + full
    /// adjacency).  Two graphs fingerprint equal iff their CSR arrays
    /// are identical.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        h.mix(self.n() as u64);
        for &o in &self.offsets {
            h.mix(o as u64);
        }
        for &t in &self.targets {
            h.mix(t as u64);
        }
        h.finish()
    }
}

/// Per-node split assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// A node-classification dataset: graph + features + labels + split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: Graph,
    /// (n, d_in) node features.
    pub features: Matrix,
    /// Node labels in [0, n_class).
    pub labels: Vec<u32>,
    pub n_class: usize,
    pub split: Vec<Split>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn d_in(&self) -> usize {
        self.features.cols
    }

    pub fn nodes_in_split(&self, s: Split) -> Vec<usize> {
        (0..self.n()).filter(|&v| self.split[v] == s).collect()
    }

    /// Content fingerprint of everything inference depends on: the
    /// graph structure plus the feature matrix (shape and exact f32
    /// bits).  Labels and split assignments are deliberately excluded —
    /// they do not enter a forward pass.  `serve::InferenceModel`
    /// records this value at export so an engine serving a *different*
    /// graph (other dataset, or the same dataset generated from another
    /// seed) refuses the model with a structured error instead of
    /// silently producing garbage.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        h.mix(self.graph.fingerprint());
        h.mix(self.features.rows as u64);
        h.mix(self.features.cols as u64);
        for &v in &self.features.data {
            h.mix_f32(v);
        }
        h.finish()
    }

    /// Basic structural validation (used by tests and the CLI loader).
    pub fn validate(&self) -> crate::Result<()> {
        let n = self.n();
        if self.features.rows != n {
            return Err(crate::eyre!("features rows {} != n {}", self.features.rows, n));
        }
        if self.labels.len() != n || self.split.len() != n {
            return Err(crate::eyre!("labels/split length mismatch"));
        }
        if let Some(&l) = self.labels.iter().find(|&&l| l as usize >= self.n_class) {
            return Err(crate::eyre!("label {l} >= n_class {}", self.n_class));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> Graph {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        Graph::from_edges(n, &edges)
    }

    #[test]
    fn csr_from_edges_basic() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 1)]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 3); // duplicate dropped
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(0), 1);
        assert!(g.has_edge(2, 3));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn self_loops_dropped() {
        let g = Graph::from_edges(3, &[(0, 0), (0, 1)]);
        assert_eq!(g.m(), 1);
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn degrees_and_stats() {
        let g = path_graph(5);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 8.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn norm_weight_symmetric() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        assert!((g.norm_weight(0, 1) - g.norm_weight(1, 0)).abs() < 1e-9);
        // d0=1, d1=2 -> 1/sqrt(2*3)
        assert!((g.norm_weight(0, 1) - 1.0 / 6.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn fingerprints_detect_structure_and_feature_changes() {
        let g = path_graph(4);
        let mut ds = Dataset {
            name: "fp".into(),
            graph: g.clone(),
            features: Matrix::from_fn(4, 2, |r, c| (r * 2 + c) as f32),
            labels: vec![0; 4],
            n_class: 2,
            split: vec![Split::Train; 4],
        };
        let base = ds.fingerprint();
        assert_eq!(base, ds.fingerprint(), "deterministic");
        // labels/splits are not inference inputs: same fingerprint
        ds.labels = vec![1; 4];
        ds.split[0] = Split::Val;
        assert_eq!(base, ds.fingerprint());
        // a feature bit flips it
        ds.features.set(0, 0, 0.5);
        assert_ne!(base, ds.fingerprint());
        // a structure change flips the graph fingerprint
        let g2 = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]);
        assert_ne!(g.fingerprint(), g2.fingerprint());
    }

    #[test]
    fn validate_catches_bad_labels() {
        let g = path_graph(3);
        let ds = Dataset {
            name: "bad".into(),
            graph: g,
            features: Matrix::zeros(3, 2),
            labels: vec![0, 1, 5],
            n_class: 2,
            split: vec![Split::Train; 3],
        };
        assert!(ds.validate().is_err());
    }
}
