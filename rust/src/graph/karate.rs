//! Zachary's karate club — the one real graph small enough to embed.
//!
//! Used by unit/integration tests and the quickstart example as a
//! ground-truth sanity workload: 34 nodes, 78 edges, 4 communities (the
//! standard modularity-based community assignment).  Features are
//! community-centroid + noise in 16 dims so the GNN task is learnable.

use super::Dataset;
use crate::graph::Graph;
use crate::tensor::Matrix;
use crate::util::Rng;

/// The 78 undirected edges of Zachary's karate club (0-indexed).
pub const KARATE_EDGES: [(u32, u32); 78] = [
    (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 10),
    (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31), (1, 2),
    (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30), (2, 3),
    (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32), (3, 7),
    (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16), (6, 16),
    (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32), (14, 33),
    (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32), (20, 33),
    (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32), (23, 33),
    (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33), (27, 33),
    (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33), (31, 32),
    (31, 33), (32, 33),
];

/// Standard 4-community modularity assignment (Newman).
pub const KARATE_COMMUNITIES: [u32; 34] = [
    0, 0, 0, 0, 1, 1, 1, 0, 2, 2, 1, 0, 0, 0, 2, 2, 1, 0, 2, 0, 2, 0, 2, 3,
    3, 3, 2, 3, 3, 2, 2, 3, 2, 2,
];

/// Build the karate dataset with synthetic class-informative features.
pub fn karate(seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let graph = Graph::from_edges(34, &KARATE_EDGES);
    let labels = KARATE_COMMUNITIES.to_vec();
    let d = 16;
    let k = 4;
    let mut centroids = Matrix::zeros(k, d);
    for c in 0..k {
        for j in 0..d {
            centroids.set(c, j, rng.normal() * 2.0);
        }
    }
    let mut features = Matrix::zeros(34, d);
    for v in 0..34 {
        let c = labels[v] as usize;
        for j in 0..d {
            features.set(v, j, centroids.get(c, j) + 0.5 * rng.normal());
        }
    }
    // 50/25/25 split, stratified
    let split = super::splits::stratified_split(&labels, k, 0.5, 0.25, &mut rng);
    Dataset {
        name: "karate".into(),
        graph,
        features,
        labels,
        n_class: k,
        split,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Split;

    #[test]
    fn karate_structure() {
        let ds = karate(0);
        assert_eq!(ds.n(), 34);
        assert_eq!(ds.graph.m(), 78);
        // node 33 (the instructor) has the max degree, 17
        assert_eq!(ds.graph.degree(33), 17);
        assert_eq!(ds.graph.max_degree(), 17);
        ds.validate().unwrap();
    }

    #[test]
    fn karate_split_covers_all() {
        let ds = karate(3);
        assert!(ds.nodes_in_split(Split::Train).len() >= 15);
        assert!(!ds.nodes_in_split(Split::Val).is_empty());
        assert!(!ds.nodes_in_split(Split::Test).is_empty());
    }
}
