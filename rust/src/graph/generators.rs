//! Synthetic graph generators: stochastic block model (SBM) and a
//! degree-skewed (power-law) variant.
//!
//! The SBM is the substitution for the paper's OGB datasets (DESIGN.md
//! §2): community structure controls partition cut size (and therefore
//! halo ratios / staleness error), while intra/inter edge probabilities
//! control density.  Features are class-centroid + Gaussian noise so the
//! task is learnable but not trivial; label = community.

use super::{Dataset, Graph};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Parameters for the SBM dataset generator.
#[derive(Debug, Clone)]
pub struct SbmParams {
    pub name: String,
    pub nodes: usize,
    pub communities: usize,
    /// Expected intra-community degree per node.
    pub intra_degree: f64,
    /// Expected inter-community degree per node.
    pub inter_degree: f64,
    pub d_in: usize,
    /// Feature signal-to-noise: centroid scale relative to unit noise.
    pub signal: f32,
    /// Degree skew: 0 = uniform; > 0 mixes in a Chung-Lu power-law
    /// weight w_i ∝ (i+1)^-skew within each community.
    pub skew: f64,
    /// Fraction of nodes whose *label* is resampled uniformly while
    /// their edges/features stay with the true community — irreducible
    /// error that keeps F1 off the ceiling (real graphs are noisy).
    pub label_noise: f64,
    /// (train, val) fractions; test is the remainder.
    pub train_frac: f64,
    pub val_frac: f64,
    pub seed: u64,
}

impl SbmParams {
    /// Expected edges: n * (intra + inter) / 2.
    pub fn expected_edges(&self) -> f64 {
        self.nodes as f64 * (self.intra_degree + self.inter_degree) / 2.0
    }
}

/// Generate an SBM dataset.  Deterministic in `params.seed`.
pub fn generate_sbm(p: &SbmParams) -> Dataset {
    assert!(p.communities >= 1 && p.nodes >= p.communities);
    let mut rng = Rng::new(p.seed);
    let n = p.nodes;
    let k = p.communities;

    // community assignment: contiguous blocks shuffled for realism
    let mut labels: Vec<u32> = (0..n).map(|v| (v % k) as u32).collect();
    rng.shuffle(&mut labels);

    // node weights for degree skew (Chung-Lu style)
    let weights: Vec<f64> = (0..n)
        .map(|i| if p.skew > 0.0 { (i as f64 + 1.0).powf(-p.skew) } else { 1.0 })
        .collect();
    let mean_w = weights.iter().sum::<f64>() / n as f64;

    // group nodes by community for targeted sampling
    let mut by_comm: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &c) in labels.iter().enumerate() {
        by_comm[c as usize].push(v as u32);
    }

    // Sample edges: for each node draw ~Poisson(intra) partners in its
    // community and ~Poisson(inter) outside, weight-biased.  Using
    // per-node target counts keeps generation O(E) instead of O(n^2).
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity((p.expected_edges() * 1.2) as usize);
    for v in 0..n {
        let c = labels[v] as usize;
        let bias = weights[v] / mean_w;
        let intra_n = sample_count(&mut rng, p.intra_degree / 2.0 * bias);
        let inter_n = sample_count(&mut rng, p.inter_degree / 2.0 * bias);
        for _ in 0..intra_n {
            let peers = &by_comm[c];
            if peers.len() > 1 {
                let u = peers[rng.below(peers.len())];
                if u as usize != v {
                    edges.push((v as u32, u));
                }
            }
        }
        for _ in 0..inter_n {
            if k > 1 {
                let mut oc = rng.below(k);
                if oc == c {
                    oc = (oc + 1) % k;
                }
                let peers = &by_comm[oc];
                if !peers.is_empty() {
                    edges.push((v as u32, peers[rng.below(peers.len())]));
                }
            }
        }
    }
    let graph = Graph::from_edges(n, &edges);

    // features: community centroid (random unit-ish direction * signal) + noise
    let mut centroids = Matrix::zeros(k, p.d_in);
    for c in 0..k {
        for j in 0..p.d_in {
            centroids.set(c, j, rng.normal() * p.signal);
        }
    }
    let mut features = Matrix::zeros(n, p.d_in);
    for v in 0..n {
        let c = labels[v] as usize;
        for j in 0..p.d_in {
            features.set(v, j, centroids.get(c, j) + rng.normal());
        }
    }

    // label noise: flip after edges/features so the graph keeps its
    // community structure but the target has irreducible error
    let mut labels = labels;
    if p.label_noise > 0.0 {
        for l in labels.iter_mut() {
            if rng.chance(p.label_noise) {
                *l = rng.below(k) as u32;
            }
        }
    }

    let split = super::splits::stratified_split(
        &labels, k, p.train_frac, p.val_frac, &mut rng,
    );

    let ds = Dataset {
        name: p.name.clone(),
        graph,
        features,
        labels,
        n_class: k,
        split,
    };
    debug_assert!(ds.validate().is_ok());
    ds
}

/// Poisson-ish integer draw with mean `lambda` (normal approximation for
/// large lambda, inversion for small — adequate for edge-count sampling).
fn sample_count(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda > 16.0 {
        let v = lambda + rng.normal() as f64 * lambda.sqrt();
        return v.max(0.0).round() as usize;
    }
    // Knuth inversion
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut prod = rng.f64();
    while prod > l && k < 1000 {
        k += 1;
        prod *= rng.f64();
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> SbmParams {
        SbmParams {
            name: "t".into(),
            nodes: 400,
            communities: 4,
            intra_degree: 8.0,
            inter_degree: 2.0,
            d_in: 8,
            signal: 1.5,
            skew: 0.0,
            label_noise: 0.0,
            train_frac: 0.5,
            val_frac: 0.25,
            seed: 1,
        }
    }

    #[test]
    fn sbm_deterministic() {
        let a = generate_sbm(&small_params());
        let b = generate_sbm(&small_params());
        assert_eq!(a.graph.targets, b.graph.targets);
        assert_eq!(a.features.data, b.features.data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn sbm_density_near_target() {
        let ds = generate_sbm(&small_params());
        let avg = ds.graph.avg_degree();
        // target total degree = 10; duplicate-collapse loses a bit
        assert!(avg > 6.0 && avg < 12.0, "avg degree {avg}");
    }

    #[test]
    fn sbm_community_structure_dominates() {
        let ds = generate_sbm(&small_params());
        let g = &ds.graph;
        let mut intra = 0usize;
        let mut inter = 0usize;
        for v in 0..g.n() {
            for &u in g.neighbors(v) {
                if ds.labels[v] == ds.labels[u as usize] {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        assert!(intra > 2 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn sbm_balanced_communities() {
        let ds = generate_sbm(&small_params());
        let mut counts = vec![0usize; 4];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 400);
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn skew_creates_heavier_tail() {
        let mut p = small_params();
        p.nodes = 2000;
        p.intra_degree = 10.0;
        let uniform = generate_sbm(&p);
        p.skew = 1.0;
        p.seed = 2;
        let skewed = generate_sbm(&p);
        assert!(skewed.graph.max_degree() > uniform.graph.max_degree());
    }

    #[test]
    fn features_carry_class_signal() {
        let ds = generate_sbm(&small_params());
        // nearest-centroid classification on raw features should beat chance
        let k = ds.n_class;
        let d = ds.d_in();
        let mut centroids = vec![vec![0f64; d]; k];
        let mut counts = vec![0usize; k];
        for v in 0..ds.n() {
            let c = ds.labels[v] as usize;
            counts[c] += 1;
            for j in 0..d {
                centroids[c][j] += ds.features.get(v, j) as f64;
            }
        }
        for c in 0..k {
            for j in 0..d {
                centroids[c][j] /= counts[c] as f64;
            }
        }
        let mut correct = 0usize;
        for v in 0..ds.n() {
            let mut best = 0;
            let mut bestd = f64::MAX;
            for c in 0..k {
                let dist: f64 = (0..d)
                    .map(|j| {
                        let diff = ds.features.get(v, j) as f64 - centroids[c][j];
                        diff * diff
                    })
                    .sum();
                if dist < bestd {
                    bestd = dist;
                    best = c;
                }
            }
            if best == ds.labels[v] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n() as f64;
        assert!(acc > 0.6, "nearest-centroid acc {acc}");
    }
}
