//! Graph statistics used by dataset reports and experiment logs.

use super::Graph;

#[derive(Debug, Clone)]
pub struct GraphStats {
    pub nodes: usize,
    pub edges: usize,
    pub avg_degree: f64,
    pub max_degree: usize,
    pub isolated: usize,
    /// Degree at the 50th / 90th / 99th percentile.
    pub deg_p50: usize,
    pub deg_p90: usize,
    pub deg_p99: usize,
}

pub fn graph_stats(g: &Graph) -> GraphStats {
    let n = g.n();
    let mut degs: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
    degs.sort_unstable();
    let pct = |p: f64| -> usize {
        if n == 0 {
            0
        } else {
            degs[((n as f64 - 1.0) * p) as usize]
        }
    };
    GraphStats {
        nodes: n,
        edges: g.m(),
        avg_degree: g.avg_degree(),
        max_degree: g.max_degree(),
        isolated: degs.iter().filter(|&&d| d == 0).count(),
        deg_p50: pct(0.5),
        deg_p90: pct(0.9),
        deg_p99: pct(0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn stats_on_star_graph() {
        // star: node 0 connected to 1..=9
        let edges: Vec<(u32, u32)> = (1..10).map(|i| (0, i)).collect();
        let g = Graph::from_edges(10, &edges);
        let s = graph_stats(&g);
        assert_eq!(s.nodes, 10);
        assert_eq!(s.edges, 9);
        assert_eq!(s.max_degree, 9);
        assert_eq!(s.isolated, 0);
        assert_eq!(s.deg_p50, 1);
    }

    #[test]
    fn isolated_counted() {
        let g = Graph::from_edges(4, &[(0, 1)]);
        assert_eq!(graph_stats(&g).isolated, 2);
    }
}
