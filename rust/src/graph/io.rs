//! Dataset file I/O: load and save node-classification datasets in a
//! simple text format, so users can bring real graphs instead of the
//! synthetic registry ones.
//!
//! Format (one directory per dataset):
//!
//! * `edges.tsv`    — one `u<TAB>v` pair per line (undirected, 0-indexed)
//! * `features.tsv` — one row per node, tab-separated f32 values
//! * `labels.tsv`   — one line per node: `label<TAB>split` where split ∈
//!   {train, val, test}
//! * `meta.json`    — `{"name": ..., "n_class": ...}`
//!
//! The quickstart docs show exporting karate with `save` and training on
//! the re-imported copy.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use super::{Dataset, Graph, Split};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::{eyre, Result};

/// Save a dataset to `dir` (created if missing).
pub fn save(ds: &Dataset, dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).map_err(|e| eyre!("creating {dir:?}: {e}"))?;

    let mut edges = BufWriter::new(
        std::fs::File::create(dir.join("edges.tsv")).map_err(|e| eyre!("{e}"))?,
    );
    for v in 0..ds.n() {
        for &u in ds.graph.neighbors(v) {
            if (u as usize) > v {
                writeln!(edges, "{v}\t{u}").map_err(|e| eyre!("{e}"))?;
            }
        }
    }
    edges.flush().map_err(|e| eyre!("{e}"))?;

    let mut feats = BufWriter::new(
        std::fs::File::create(dir.join("features.tsv")).map_err(|e| eyre!("{e}"))?,
    );
    for v in 0..ds.n() {
        let row: Vec<String> = ds.features.row(v).iter().map(|x| x.to_string()).collect();
        writeln!(feats, "{}", row.join("\t")).map_err(|e| eyre!("{e}"))?;
    }
    feats.flush().map_err(|e| eyre!("{e}"))?;

    let mut labels = BufWriter::new(
        std::fs::File::create(dir.join("labels.tsv")).map_err(|e| eyre!("{e}"))?,
    );
    for v in 0..ds.n() {
        let split = match ds.split[v] {
            Split::Train => "train",
            Split::Val => "val",
            Split::Test => "test",
        };
        writeln!(labels, "{}\t{}", ds.labels[v], split).map_err(|e| eyre!("{e}"))?;
    }
    labels.flush().map_err(|e| eyre!("{e}"))?;

    let meta = Json::obj(vec![
        ("name", Json::str(ds.name.clone())),
        ("n_class", Json::num(ds.n_class as f64)),
        ("nodes", Json::num(ds.n() as f64)),
    ]);
    std::fs::write(dir.join("meta.json"), meta.to_string()).map_err(|e| eyre!("{e}"))?;
    Ok(())
}

/// Load a dataset from `dir` (the format written by [`save`]).
pub fn load(dir: impl AsRef<Path>) -> Result<Dataset> {
    let dir = dir.as_ref();
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))
        .map_err(|e| eyre!("reading {dir:?}/meta.json: {e}"))?;
    let meta = Json::parse(&meta_text)?;
    let name = meta.get("name")?.as_str()?.to_string();
    let n_class = meta.get("n_class")?.as_usize()?;

    // labels + splits determine n
    let labels_file =
        std::fs::File::open(dir.join("labels.tsv")).map_err(|e| eyre!("labels.tsv: {e}"))?;
    let mut labels = Vec::new();
    let mut split = Vec::new();
    for (i, line) in std::io::BufReader::new(labels_file).lines().enumerate() {
        let line = line.map_err(|e| eyre!("{e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let (l, s) = line
            .split_once('\t')
            .ok_or_else(|| eyre!("labels.tsv line {}: need label<TAB>split", i + 1))?;
        labels.push(l.trim().parse::<u32>().map_err(|e| eyre!("label: {e}"))?);
        split.push(match s.trim() {
            "train" => Split::Train,
            "val" => Split::Val,
            "test" => Split::Test,
            other => return Err(eyre!("unknown split {other:?} at line {}", i + 1)),
        });
    }
    let n = labels.len();

    // features
    let feats_file = std::fs::File::open(dir.join("features.tsv"))
        .map_err(|e| eyre!("features.tsv: {e}"))?;
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(n);
    for line in std::io::BufReader::new(feats_file).lines() {
        let line = line.map_err(|e| eyre!("{e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        rows.push(
            line.split('\t')
                .map(|t| t.trim().parse::<f32>().map_err(|e| eyre!("feature: {e}")))
                .collect::<Result<_>>()?,
        );
    }
    if rows.len() != n {
        return Err(eyre!("features rows {} != labels {}", rows.len(), n));
    }
    let d = rows.first().map_or(0, |r| r.len());
    if rows.iter().any(|r| r.len() != d) {
        return Err(eyre!("ragged feature rows"));
    }
    let mut features = Matrix::zeros(n, d);
    for (v, row) in rows.iter().enumerate() {
        features.copy_row_from(v, row);
    }

    // edges
    let edges_file =
        std::fs::File::open(dir.join("edges.tsv")).map_err(|e| eyre!("edges.tsv: {e}"))?;
    let mut edges = Vec::new();
    for (i, line) in std::io::BufReader::new(edges_file).lines().enumerate() {
        let line = line.map_err(|e| eyre!("{e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let (a, b) = line
            .split_once('\t')
            .ok_or_else(|| eyre!("edges.tsv line {}: need u<TAB>v", i + 1))?;
        edges.push((
            a.trim().parse::<u32>().map_err(|e| eyre!("edge: {e}"))?,
            b.trim().parse::<u32>().map_err(|e| eyre!("edge: {e}"))?,
        ));
    }
    let graph = Graph::from_edges(n, &edges);

    let ds = Dataset {
        name,
        graph,
        features,
        labels,
        n_class,
        split,
    };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("digest_io_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_round_trips_karate() {
        let ds = karate(7);
        let dir = tmpdir("karate");
        save(&ds, &dir).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.n_class, ds.n_class);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.split, ds.split);
        assert_eq!(back.graph.offsets, ds.graph.offsets);
        assert_eq!(back.graph.targets, ds.graph.targets);
        assert!(back.features.max_abs_diff(&ds.features) < 1e-5);
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(load("/nonexistent/digest/dataset").is_err());
    }

    #[test]
    fn load_rejects_corrupt_labels() {
        let ds = karate(1);
        let dir = tmpdir("corrupt");
        save(&ds, &dir).unwrap();
        std::fs::write(dir.join("labels.tsv"), "0\tbogus\n").unwrap();
        assert!(load(&dir).is_err());
    }

    #[test]
    fn load_rejects_ragged_features() {
        let ds = karate(2);
        let dir = tmpdir("ragged");
        save(&ds, &dir).unwrap();
        std::fs::write(dir.join("features.tsv"), "1.0\t2.0\n1.0\n").unwrap();
        assert!(load(&dir).is_err());
    }

    #[test]
    fn sbm_round_trip_preserves_structure() {
        use crate::graph::registry;
        let ds = registry::load("flickr-s", 3).unwrap();
        let dir = tmpdir("flickr");
        save(&ds, &dir).unwrap();
        let back = super::load(&dir).unwrap();
        assert_eq!(back.graph.m(), ds.graph.m());
        assert_eq!(back.labels, ds.labels);
    }
}
