//! Dataset registry: the paper's four benchmarks as synthetic stand-ins.
//!
//! Each entry mirrors one of the paper's datasets (Table 3) at CI scale,
//! preserving the *relative* properties the experiments depend on:
//! density ranking (reddit ≫ flickr > arxiv ≈ products), feature
//! dimension ranking, class counts, split fractions, and community
//! strength (products/arxiv cluster well → low halo ratio; flickr/reddit
//! are cross-linked → high halo ratio, cf. paper Fig. 9).
//!
//! Every dataset maps to the AOT artifact config prefix whose padded
//! shapes fit an M=4 partition (see python/compile/configs.py — the two
//! sides must stay in lockstep).
//!
//! The `-m` ("medium") tiers are eval-scale stand-ins (50k–150k nodes)
//! for the sparse evaluation path and `benches/bench_eval.rs`: large
//! enough that the seed dense-loop oracle visibly collapses, still
//! generatable in seconds.  They have **no AOT artifacts yet** (training
//! on them errors at manifest lookup) and are deliberately kept out of
//! the tier-1 test configs — only the benches and explicit CLI use load
//! them.

use super::generators::{generate_sbm, SbmParams};
use super::karate::karate;
use super::Dataset;

/// Descriptor for a named dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Paper dataset this one substitutes.
    pub paper_name: &'static str,
    pub nodes: usize,
    pub n_class: usize,
    pub d_in: usize,
    pub intra_degree: f64,
    pub inter_degree: f64,
    pub skew: f64,
    pub train_frac: f64,
    pub val_frac: f64,
    /// Artifact config prefix ("arxiv_s" -> arxiv_s_gcn / arxiv_s_gat).
    pub artifact: &'static str,
    /// Default partition count the artifact shapes were sized for.
    pub default_parts: usize,
}

pub const SPECS: [DatasetSpec; 7] = [
    DatasetSpec {
        name: "karate",
        paper_name: "Zachary karate (sanity)",
        nodes: 34,
        n_class: 4,
        d_in: 16,
        intra_degree: 0.0, // real graph, generator unused
        inter_degree: 0.0,
        skew: 0.0,
        train_frac: 0.5,
        val_frac: 0.25,
        artifact: "karate",
        default_parts: 2,
    },
    DatasetSpec {
        name: "arxiv-s",
        paper_name: "OGB-Arxiv",
        nodes: 2048,
        n_class: 40,
        d_in: 128,
        intra_degree: 10.0,
        inter_degree: 3.0,
        skew: 0.5,
        train_frac: 0.537,
        val_frac: 0.176,
        artifact: "arxiv_s",
        default_parts: 4,
    },
    DatasetSpec {
        name: "flickr-s",
        paper_name: "Flickr",
        nodes: 1024,
        n_class: 7,
        d_in: 200,
        intra_degree: 6.0,
        inter_degree: 4.0, // weak communities -> high halo ratio
        skew: 0.8,
        train_frac: 0.5,
        val_frac: 0.25,
        artifact: "flickr_s",
        default_parts: 4,
    },
    DatasetSpec {
        name: "reddit-s",
        paper_name: "Reddit",
        nodes: 1024,
        n_class: 41,
        d_in: 300,
        intra_degree: 25.0,
        inter_degree: 15.0, // densest graph, heavy cross edges
        skew: 0.6,
        train_frac: 0.66,
        val_frac: 0.10,
        artifact: "reddit_s",
        default_parts: 4,
    },
    DatasetSpec {
        name: "products-s",
        paper_name: "OGB-Products",
        nodes: 4096,
        n_class: 47,
        d_in: 100,
        intra_degree: 11.0,
        inter_degree: 1.5, // strong clusters -> low halo ratio
        skew: 0.7,
        train_frac: 0.08,
        val_frac: 0.02,
        artifact: "products_s",
        default_parts: 4,
    },
    DatasetSpec {
        name: "arxiv-m",
        paper_name: "OGB-Arxiv (eval scale)",
        nodes: 65536,
        n_class: 40,
        d_in: 128,
        intra_degree: 10.0,
        inter_degree: 3.0,
        skew: 0.5,
        train_frac: 0.537,
        val_frac: 0.176,
        artifact: "arxiv_m", // not built yet: eval/bench tier only
        default_parts: 8,
    },
    DatasetSpec {
        name: "reddit-m",
        paper_name: "Reddit (eval scale)",
        nodes: 131072,
        n_class: 41,
        d_in: 300,
        intra_degree: 60.0,
        inter_degree: 40.0, // paper Reddit averages ~100 neighbors
        skew: 0.6,
        train_frac: 0.66,
        val_frac: 0.10,
        artifact: "reddit_m", // not built yet: eval/bench tier only
        default_parts: 8,
    },
];

pub fn spec(name: &str) -> crate::Result<&'static DatasetSpec> {
    SPECS
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| crate::eyre!(
            "unknown dataset {name:?}; available: {:?}",
            SPECS.iter().map(|s| s.name).collect::<Vec<_>>()
        ))
}

/// Load (generate) a dataset by registry name, deterministic in `seed`.
pub fn load(name: &str, seed: u64) -> crate::Result<Dataset> {
    let s = spec(name)?;
    if s.name == "karate" {
        return Ok(karate(seed));
    }
    Ok(generate_sbm(&SbmParams {
        name: s.name.to_string(),
        nodes: s.nodes,
        communities: s.n_class,
        intra_degree: s.intra_degree,
        inter_degree: s.inter_degree,
        d_in: s.d_in,
        // calibrated so raw features alone classify at ~20-40% — the GNN
        // must exploit neighborhood structure to do better, which is what
        // separates the frameworks in Table 1 (edge-dropping hurts)
        signal: 1.3 / (s.d_in as f32).sqrt(),
        skew: s.skew,
        // irreducible label noise keeps F1 off the 1.0 ceiling
        label_noise: 0.08,
        train_frac: s.train_frac,
        val_frac: s.val_frac,
        seed,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_load_and_validate() {
        for s in &SPECS {
            // keep the big ones out of unit tests; integration covers them
            if s.nodes > 1100 {
                continue;
            }
            let ds = load(s.name, 42).unwrap();
            ds.validate().unwrap();
            assert_eq!(ds.n(), s.nodes);
            assert_eq!(ds.n_class, s.n_class);
            assert_eq!(ds.d_in(), s.d_in);
        }
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(load("nope", 0).is_err());
    }

    #[test]
    fn medium_tiers_registered_but_not_generated_here() {
        // the -m tiers exist (bench + CLI use them) but stay out of
        // tier-1 generation: 50k+-node SBMs are bench-only workloads
        for name in ["arxiv-m", "reddit-m"] {
            let s = spec(name).unwrap();
            assert!(s.nodes >= 50_000, "{name} is an eval-scale tier");
            assert!(s.artifact.ends_with("_m"));
        }
        // density ranking preserved at scale: reddit ≫ arxiv
        let (a, r) = (spec("arxiv-m").unwrap(), spec("reddit-m").unwrap());
        assert!(r.intra_degree + r.inter_degree > 3.0 * (a.intra_degree + a.inter_degree));
    }

    #[test]
    fn density_ranking_matches_paper() {
        let flickr = load("flickr-s", 1).unwrap();
        let reddit = load("reddit-s", 1).unwrap();
        assert!(
            reddit.graph.avg_degree() > 2.0 * flickr.graph.avg_degree(),
            "reddit {} vs flickr {}",
            reddit.graph.avg_degree(),
            flickr.graph.avg_degree()
        );
    }
}
