//! Stratified train/val/test splits (per-class proportional sampling),
//! matching the paper's per-dataset split fractions (Table 3).

use super::Split;
use crate::util::Rng;

/// Assign each node a split, stratified by label so every class appears
/// in every split (when large enough).
pub fn stratified_split(
    labels: &[u32],
    n_class: usize,
    train_frac: f64,
    val_frac: f64,
    rng: &mut Rng,
) -> Vec<Split> {
    assert!(train_frac >= 0.0 && val_frac >= 0.0 && train_frac + val_frac <= 1.0);
    let n = labels.len();
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); n_class];
    for (v, &l) in labels.iter().enumerate() {
        by_class[l as usize].push(v);
    }
    let mut split = vec![Split::Test; n];
    for nodes in by_class.iter_mut() {
        rng.shuffle(nodes);
        let n_train = (nodes.len() as f64 * train_frac).round() as usize;
        let n_val = (nodes.len() as f64 * val_frac).round() as usize;
        for (i, &v) in nodes.iter().enumerate() {
            split[v] = if i < n_train {
                Split::Train
            } else if i < n_train + n_val {
                Split::Val
            } else {
                Split::Test
            };
        }
    }
    split
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_respected() {
        let mut rng = Rng::new(0);
        let labels: Vec<u32> = (0..1000).map(|i| (i % 5) as u32).collect();
        let split = stratified_split(&labels, 5, 0.5, 0.25, &mut rng);
        let train = split.iter().filter(|&&s| s == Split::Train).count();
        let val = split.iter().filter(|&&s| s == Split::Val).count();
        let test = split.iter().filter(|&&s| s == Split::Test).count();
        assert_eq!(train, 500);
        assert_eq!(val, 250);
        assert_eq!(test, 250);
    }

    #[test]
    fn stratification_per_class() {
        let mut rng = Rng::new(1);
        let labels: Vec<u32> = (0..300).map(|i| (i % 3) as u32).collect();
        let split = stratified_split(&labels, 3, 0.6, 0.2, &mut rng);
        for c in 0..3u32 {
            let train_c = labels
                .iter()
                .zip(&split)
                .filter(|(&l, &s)| l == c && s == Split::Train)
                .count();
            assert_eq!(train_c, 60);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let labels: Vec<u32> = (0..100).map(|i| (i % 4) as u32).collect();
        let a = stratified_split(&labels, 4, 0.5, 0.3, &mut Rng::new(7));
        let b = stratified_split(&labels, 4, 0.5, 0.3, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
