//! Sparse CSR matrices and the allocation-free SpMM kernel behind the
//! full-graph evaluation path.
//!
//! The dense [`Matrix`](super::Matrix) is the right shape for the padded
//! AOT artifacts, but the *global* propagation matrix of a 100k-node
//! graph is ~10⁻⁴ dense — materializing it (or walking the graph with a
//! per-edge `Vec` allocation, as the seed oracle did) collapses long
//! before ROADMAP scale.  [`CsrMatrix`] stores only the nonzeros and
//! [`CsrMatrix::spmm_into`] runs `out = self × dense` without a single
//! allocation in the loop.
//!
//! ## Determinism contract
//!
//! [`CsrMatrix::spmm_into_threaded`] parallelizes over *contiguous row
//! chunks* (balanced by nonzero count): every output row is written by
//! exactly one thread, and within a row the accumulation order is the
//! CSR entry order regardless of chunking.  Results are therefore
//! **bit-identical at any thread count** — the same guarantee the
//! coordinator's parallel engine (`coordinator::engine`) established for
//! training, extended here to evaluation.
//!
//! Entry order within a row is whatever the builder pushed — it is part
//! of the numeric contract (f32 addition is non-associative), so the
//! GNN builders deliberately push the self-loop first and neighbors in
//! ascending id order to reproduce the seed oracle's summation order.

use super::Matrix;
use crate::{eyre, Result};

/// Compressed-sparse-row f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row offsets into `col_idx`/`values`, length `rows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column index of each nonzero.
    pub col_idx: Vec<u32>,
    /// Value of each nonzero (row-major by `row_ptr`).
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Assemble from raw CSR arrays, validating every structural
    /// invariant (monotone offsets, column bounds, matching lengths).
    pub fn new(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if row_ptr.len() != rows + 1 {
            return Err(eyre!("row_ptr len {} != rows + 1 ({})", row_ptr.len(), rows + 1));
        }
        if row_ptr[0] != 0 || row_ptr[rows] != col_idx.len() {
            return Err(eyre!("row_ptr must span [0, nnz={}]", col_idx.len()));
        }
        if row_ptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(eyre!("row_ptr not monotone"));
        }
        if col_idx.len() != values.len() {
            return Err(eyre!("col_idx len {} != values len {}", col_idx.len(), values.len()));
        }
        if let Some(&c) = col_idx.iter().find(|&&c| c as usize >= cols) {
            return Err(eyre!("column {c} out of range (cols = {cols})"));
        }
        // duplicate columns in a row would make SpMM (sums entries) and
        // densification (last write wins) disagree about the matrix
        for r in 0..rows {
            let row = &col_idx[row_ptr[r]..row_ptr[r + 1]];
            for (i, c) in row.iter().enumerate() {
                if row[..i].contains(c) {
                    return Err(eyre!("duplicate column {c} in row {r}"));
                }
            }
        }
        Ok(CsrMatrix { rows, cols, row_ptr, col_idx, values })
    }

    /// All-zero matrix (no stored entries).
    pub fn empty(rows: usize, cols: usize) -> Self {
        CsrMatrix {
            rows,
            cols,
            row_ptr: vec![0; rows + 1],
            col_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Sparsify a dense matrix (exact zeros dropped).  Test/bench
    /// convenience — production builders construct CSR directly.
    pub fn from_dense(m: &Matrix) -> Self {
        let mut b = CsrBuilder::new(m.rows, m.cols);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    b.push(c as u32, v);
                }
            }
            b.finish_row();
        }
        b.finish()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// (column indices, values) of row `r`.
    #[inline]
    pub fn row_entries(&self, r: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.row_ptr[r], self.row_ptr[r + 1]);
        (&self.col_idx[lo..hi], &self.values[lo..hi])
    }

    /// Point lookup (linear scan of the row — fine for tests and
    /// plan inspection, not meant for hot loops).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (cols, vals) = self.row_entries(r);
        cols.iter()
            .position(|&ci| ci as usize == c)
            .map_or(0.0, |i| vals[i])
    }

    /// Densify.  Scatter order is irrelevant for the result (each entry
    /// has a distinct slot), so this reproduces the dense construction
    /// byte-for-byte when the values were computed identically.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        self.scatter_into(&mut m.data);
        m
    }

    /// Scatter the nonzeros into a caller-provided row-major buffer of
    /// `rows * cols` zeros (the literal-packing path densifies straight
    /// into the staging buffer instead of an intermediate `Matrix`).
    pub fn scatter_into(&self, flat: &mut [f32]) {
        assert_eq!(flat.len(), self.rows * self.cols, "scatter buffer shape mismatch");
        for r in 0..self.rows {
            let (cols, vals) = self.row_entries(r);
            let base = r * self.cols;
            for (&c, &v) in cols.iter().zip(vals) {
                flat[base + c as usize] = v;
            }
        }
    }

    /// Per-row sums (plan-invariant checks; mirrors dense `row().sum()`).
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row_entries(r).1.iter().sum())
            .collect()
    }

    fn check_spmm_shapes(&self, dense: &Matrix, out: &Matrix) -> Result<()> {
        if self.cols != dense.rows {
            return Err(eyre!("spmm: lhs cols {} != rhs rows {}", self.cols, dense.rows));
        }
        if out.rows != self.rows || out.cols != dense.cols {
            return Err(eyre!(
                "spmm: out is {}x{}, want {}x{}",
                out.rows,
                out.cols,
                self.rows,
                dense.cols
            ));
        }
        Ok(())
    }

    /// `out = self × dense`, overwriting `out`.  Allocation-free: the
    /// only writes are into `out`'s existing buffer.
    pub fn spmm_into(&self, dense: &Matrix, out: &mut Matrix) -> Result<()> {
        self.check_spmm_shapes(dense, out)?;
        spmm_rows(&self.row_ptr, &self.col_idx, &self.values, dense, &mut out.data);
        Ok(())
    }

    /// Multithreaded `out = self × dense` on the persistent
    /// [`ChunkPool`](super::pool::ChunkPool).  Rows are split into
    /// `threads` contiguous chunks balanced by nonzero count; each
    /// output row is written by exactly one chunk, so the result is
    /// bit-identical to [`CsrMatrix::spmm_into`] at any thread count.
    /// (This used to spawn scoped threads per call; the pool removes
    /// that per-call spawn/join cost with byte-identical output.)
    pub fn spmm_into_threaded(
        &self,
        dense: &Matrix,
        out: &mut Matrix,
        threads: usize,
    ) -> Result<()> {
        self.check_spmm_shapes(dense, out)?;
        let bounds = balanced_row_chunks(&self.row_ptr, threads);
        if bounds.len() <= 2 {
            // single chunk: skip the fan-out entirely
            return self.spmm_into(dense, out);
        }
        let (row_ptr, col_idx, values) =
            (&self.row_ptr[..], &self.col_idx[..], &self.values[..]);
        let elem_bounds: Vec<usize> = bounds.iter().map(|&r| r * dense.cols).collect();
        super::pool::ChunkPool::global().run_chunks(&mut out.data, &elem_bounds, |i, chunk| {
            let (lo, hi) = (bounds[i], bounds[i + 1]);
            spmm_rows(&row_ptr[lo..=hi], col_idx, values, dense, chunk);
        });
        Ok(())
    }
}

/// Row kernel shared by the sequential and threaded paths.  `offsets`
/// is the row_ptr slice for exactly the rows being computed (its values
/// are global indices into `col_idx`/`values`); `out_rows` is those
/// rows' slice of the output buffer.
fn spmm_rows(
    offsets: &[usize],
    col_idx: &[u32],
    values: &[f32],
    dense: &Matrix,
    out_rows: &mut [f32],
) {
    let d = dense.cols;
    for (r, w) in offsets.windows(2).enumerate() {
        let orow = &mut out_rows[r * d..(r + 1) * d];
        orow.fill(0.0);
        for e in w[0]..w[1] {
            let a = values[e];
            let drow = dense.row(col_idx[e] as usize);
            for (o, x) in orow.iter_mut().zip(drow) {
                *o += a * x;
            }
        }
    }
}

/// Split `0..rows` into at most `threads` contiguous chunks with
/// roughly equal nonzero counts (rows of a power-law graph vary wildly
/// in degree; equal-row chunks would leave threads idle).  Returns the
/// chunk boundaries `[0, b1, ..., rows]`.  Deterministic in the
/// structure and thread count only — and since every row is computed
/// independently, the *result* does not depend on the boundaries.
pub fn balanced_row_chunks(row_ptr: &[usize], threads: usize) -> Vec<usize> {
    let rows = row_ptr.len() - 1;
    let threads = threads.clamp(1, rows.max(1));
    let nnz = row_ptr[rows];
    let mut bounds = vec![0usize];
    if rows == 0 {
        bounds.push(0);
        return bounds;
    }
    let mut next_target = 1usize;
    for r in 0..rows {
        // close the chunk once it reached its share of the nonzeros
        // (+ its share of rows, so empty-row regions still split)
        let share = (nnz * next_target) / threads + (rows * next_target) / threads;
        if row_ptr[r + 1] + r + 1 >= share && next_target < threads && r + 1 < rows {
            bounds.push(r + 1);
            next_target += 1;
        }
    }
    bounds.push(rows);
    bounds
}

/// Incremental row-by-row CSR assembly.
pub struct CsrBuilder {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CsrBuilder {
    pub fn new(rows: usize, cols: usize) -> Self {
        CsrBuilder {
            rows,
            cols,
            row_ptr: Vec::with_capacity(rows + 1),
            col_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Pre-size the entry arrays (builders that know |E| up front).
    pub fn reserve(&mut self, nnz: usize) {
        self.col_idx.reserve(nnz);
        self.values.reserve(nnz);
    }

    /// Append an entry to the *current* row.  Entry order within the
    /// row is preserved (it defines the summation order in SpMM).
    ///
    /// Precondition: a column appears at most once per row — SpMM would
    /// *sum* duplicates while densification last-write-wins, so a
    /// duplicate makes the two views of the matrix disagree.  The
    /// graph-derived builders satisfy this by construction (adjacency
    /// lists are deduped); `finish_row` checks it in debug builds.
    #[inline]
    pub fn push(&mut self, col: u32, val: f32) {
        debug_assert!((col as usize) < self.cols, "col {col} out of range");
        self.col_idx.push(col);
        self.values.push(val);
    }

    /// Close the current row and move to the next.  `row_ptr` collects
    /// row *end* offsets; `finish` prepends the leading 0.
    #[inline]
    pub fn finish_row(&mut self) {
        assert!(self.row_ptr.len() < self.rows, "more rows finished than declared");
        #[cfg(debug_assertions)]
        {
            let start = self.row_ptr.last().copied().unwrap_or(0);
            let row = &self.col_idx[start..];
            for (i, c) in row.iter().enumerate() {
                assert!(!row[..i].contains(c), "duplicate column {c} in row");
            }
        }
        self.row_ptr.push(self.col_idx.len());
    }

    /// Finalize; unfinished trailing rows become empty rows.
    pub fn finish(mut self) -> CsrMatrix {
        // entries pushed after the last finish_row() would otherwise be
        // silently orphaned (fully-declared builder) or smuggled into
        // the first padded row — both are caller bugs
        assert!(
            self.row_ptr.last().copied().unwrap_or(0) == self.col_idx.len(),
            "entries pushed after the final finish_row()"
        );
        while self.row_ptr.len() < self.rows {
            self.row_ptr.push(self.col_idx.len());
        }
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        row_ptr.push(0);
        row_ptr.extend_from_slice(&self.row_ptr);
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            row_ptr,
            col_idx: self.col_idx,
            values: self.values,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> CsrMatrix {
        let mut b = CsrBuilder::new(rows, cols);
        for _ in 0..rows {
            for c in 0..cols {
                if rng.chance(density) {
                    b.push(c as u32, rng.uniform(-1.0, 1.0));
                }
            }
            b.finish_row();
        }
        b.finish()
    }

    #[test]
    fn builder_round_trips_through_dense() {
        let mut b = CsrBuilder::new(3, 4);
        b.push(2, 5.0);
        b.push(0, -1.0); // out-of-column-order on purpose: order preserved
        b.finish_row();
        b.finish_row(); // empty row
        b.push(3, 2.0);
        b.finish_row();
        let m = b.finish();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 2), 5.0);
        assert_eq!(m.get(0, 0), -1.0);
        assert_eq!(m.get(1, 1), 0.0);
        assert_eq!(m.get(2, 3), 2.0);
        let d = m.to_dense();
        assert_eq!(d.get(0, 2), 5.0);
        assert_eq!(CsrMatrix::from_dense(&d).to_dense().data, d.data);
    }

    #[test]
    fn builder_pads_unfinished_rows() {
        let mut b = CsrBuilder::new(4, 2);
        b.push(1, 1.0);
        b.finish_row();
        let m = b.finish();
        assert_eq!(m.rows, 4);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_entries(3).0.len(), 0);
    }

    #[test]
    fn new_validates_structure() {
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).is_ok());
        // wrong row_ptr length
        assert!(CsrMatrix::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        // non-monotone
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 0], vec![0], vec![1.0]).is_err());
        // column out of range
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![5], vec![1.0]).is_err());
        // values length mismatch
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![0], vec![]).is_err());
        // last offset != nnz
        assert!(CsrMatrix::new(2, 2, vec![0, 0, 2], vec![0], vec![1.0]).is_err());
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let mut rng = Rng::new(7);
        for (r, k, c, density) in [(5, 6, 4, 0.5), (17, 9, 8, 0.2), (1, 3, 2, 1.0)] {
            let a = random_csr(&mut rng, r, k, density);
            let b = Matrix::from_fn(k, c, |_, _| rng.uniform(-1.0, 1.0));
            let mut out = Matrix::zeros(r, c);
            a.spmm_into(&b, &mut out).unwrap();
            let want = a.to_dense().matmul(&b);
            assert!(out.max_abs_diff(&want) < 1e-5);
        }
    }

    #[test]
    fn spmm_shape_validation() {
        let a = CsrMatrix::empty(3, 4);
        let b = Matrix::zeros(5, 2); // wrong inner dim
        let mut out = Matrix::zeros(3, 2);
        assert!(a.spmm_into(&b, &mut out).is_err());
        let b = Matrix::zeros(4, 2);
        let mut bad_out = Matrix::zeros(2, 2); // wrong out rows
        assert!(a.spmm_into(&b, &mut bad_out).is_err());
        assert!(a.spmm_into(&b, &mut out).is_ok());
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn spmm_threaded_bit_identical_any_thread_count() {
        let mut rng = Rng::new(42);
        let a = random_csr(&mut rng, 53, 31, 0.3);
        let b = Matrix::from_fn(31, 7, |_, _| rng.uniform(-2.0, 2.0));
        let mut ref_out = Matrix::zeros(53, 7);
        a.spmm_into(&b, &mut ref_out).unwrap();
        for threads in [1, 2, 3, 4, 8, 64] {
            let mut out = Matrix::zeros(53, 7);
            a.spmm_into_threaded(&b, &mut out, threads).unwrap();
            let same = out
                .data
                .iter()
                .zip(&ref_out.data)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "threads={threads} diverged");
        }
    }

    #[test]
    fn balanced_chunks_cover_and_balance() {
        // 4 heavy rows then 12 empty: nnz-balance must split the heavy part
        let mut row_ptr = vec![0usize];
        for r in 0..16 {
            let nnz = if r < 4 { 100 } else { 0 };
            row_ptr.push(row_ptr.last().unwrap() + nnz);
        }
        let b = balanced_row_chunks(&row_ptr, 4);
        assert_eq!(*b.first().unwrap(), 0);
        assert_eq!(*b.last().unwrap(), 16);
        assert!(b.windows(2).all(|w| w[0] < w[1]), "chunks non-empty: {b:?}");
        // the heavy rows must not all land in one chunk
        let first_chunk_rows = b[1];
        assert!(first_chunk_rows < 4, "heavy rows split: {b:?}");
        // degenerate inputs
        assert_eq!(balanced_row_chunks(&[0], 4), vec![0, 0]);
        assert_eq!(balanced_row_chunks(&[0, 5], 4), vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "entries pushed after the final finish_row")]
    fn builder_rejects_orphaned_entries() {
        let mut b = CsrBuilder::new(1, 2);
        b.push(0, 1.0);
        b.finish_row();
        b.push(1, 2.0); // no row left to hold this entry
        let _ = b.finish();
    }

    #[test]
    fn new_rejects_duplicate_columns() {
        assert!(CsrMatrix::new(1, 2, vec![0, 2], vec![0, 0], vec![1.0, 2.0]).is_err());
        assert!(CsrMatrix::new(1, 2, vec![0, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
    }

    #[test]
    fn row_sums_and_scatter() {
        let mut b = CsrBuilder::new(2, 3);
        b.push(0, 1.0);
        b.push(2, 2.0);
        b.finish_row();
        b.push(1, -3.0);
        b.finish_row();
        let m = b.finish();
        assert_eq!(m.row_sums(), vec![3.0, -3.0]);
        let mut flat = vec![0f32; 6];
        m.scatter_into(&mut flat);
        assert_eq!(flat, vec![1.0, 0.0, 2.0, 0.0, -3.0, 0.0]);
    }
}
