//! Persistent chunked-compute pool — the long-lived replacement for the
//! per-call `std::thread::scope` scaffold that used to be copy-pasted
//! across `spmm_into_threaded`, `par_matmul_into` and
//! `gat_attention_values`.
//!
//! Every eval-side kernel in this crate has the same shape: one flat
//! `&mut [f32]` output buffer, split at caller-chosen boundaries into
//! disjoint contiguous chunks, with a pure row kernel run per chunk.
//! Spawning and joining fresh OS threads for that on *every* SpMM /
//! matmul / attention call costs tens of microseconds per call — paid
//! once per layer per eval, thousands of times over a training run.
//! [`ChunkPool`] spawns its named worker threads **once** and feeds them
//! chunk descriptors through a generation-stamped job slot instead.
//!
//! ## Determinism contract
//!
//! The pool preserves the scoped-scaffold guarantee bit-for-bit: each
//! chunk is a disjoint slice of the output buffer, chunk boundaries are
//! chosen by the caller (not the pool), and the kernel runs over a
//! chunk's rows in fixed order.  *Which thread* runs a chunk is
//! scheduling-dependent; *what it writes* is not — so results are
//! *bit-identical at any pool size and any thread count*, exactly as
//! before the refactor.
//!
//! ## Execution / safety protocol
//!
//! `run_chunks` erases the chunk closure's lifetime into a shared
//! [`Job`] and publishes it; workers (and the calling thread, which
//! always participates) claim chunk indices from an atomic counter.
//! Soundness rests on two invariants:
//!
//! 1. the submitter does not return until every claimed chunk has
//!    finished (`completed == n`), so the borrowed closure and output
//!    buffer outlive every dereference;
//! 2. a worker dereferences the erased closure only between a
//!    *successful* claim (`i < n`) and that chunk's `completed`
//!    increment — after `completed == n` every further claim fails, so
//!    the dangling pointer left in an old [`Job`] is never touched.
//!
//! The raw-pointer aliasing in `run_chunks` is sound for the same
//! reason the old scoped scaffold was: the `&mut [f32]` windows handed
//! to chunk kernels are `data[bounds[i]..bounds[i + 1]]` for a
//! *monotone* `bounds` (asserted on entry), so any two windows are
//! disjoint and no two claimers ever hold `&mut` to the same element.
//!
//! A panic inside a chunk kernel is caught on the executing thread
//! (workers must survive it — they are long-lived), recorded on the
//! job, and re-raised on the submitting thread after the barrier.
//!
//! Jobs are serialized by a submission mutex: concurrent `run_chunks`
//! calls (e.g. tests running in parallel against the global pool)
//! queue up rather than interleave.  Re-entrant submission from inside
//! a chunk kernel would self-deadlock, so a thread-local depth flag
//! downgrades nested calls to inline sequential execution.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::util::lock_unpoisoned;

/// One published fan-out: an erased chunk closure plus the claim /
/// completion counters.  Allocated fresh per `run_chunks` call (an
/// `Arc` of a few words — noise next to the thread spawns it replaces)
/// so a late-waking worker can never mix one job's closure with a
/// newer job's counters.
struct Job {
    /// Lifetime-erased `&dyn Fn(chunk_index)`.  Dangles once the
    /// submitting call returns; see the module docs for why it is
    /// provably never dereferenced after that.
    f: *const (dyn Fn(usize) + Sync),
    /// Number of chunks.
    n: usize,
    /// Next chunk index to claim.
    next: AtomicUsize,
    /// Chunks fully executed.
    completed: AtomicUsize,
    /// A chunk kernel panicked (re-raised by the submitter).
    panicked: AtomicBool,
}

// SAFETY: `f` crosses threads, but is only dereferenced under the
// claim protocol above while the submitting stack frame is alive; the
// counters are atomics and `n` is immutable after publication.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// State guarded by the pool mutex: the current job (if any), a
/// generation stamp so sleeping workers can tell "new job" from
/// spurious wakeups, and the shutdown flag.
struct Slot {
    gen: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers wait here for a new generation.
    work_cv: Condvar,
    /// The submitter waits here for `completed == n`.
    done_cv: Condvar,
}

thread_local! {
    /// True while this thread is executing inside the pool (either
    /// submitting or running a chunk): nested submissions run inline.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// A persistent pool of named worker threads executing disjoint-slice
/// chunk kernels.  See the module docs for the contract.
pub struct ChunkPool {
    shared: Arc<Shared>,
    /// Serializes submissions; held across the whole fan-out.
    submit: Mutex<()>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl ChunkPool {
    /// Spawn a pool with `workers` persistent threads.  The submitting
    /// thread always participates in every job, so a pool sized
    /// `cores - 1` saturates the machine and `workers == 0` is a valid
    /// (fully inline) pool.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                gen: 0,
                job: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("digest-chunk-{i}"))
                    .spawn(move || worker_loop(&shared))
                    // lint:allow(D002, a process that cannot spawn its compute pool at startup has no useful degraded mode)
                    .expect("spawning pool worker")
            })
            .collect();
        ChunkPool {
            shared,
            submit: Mutex::new(()),
            workers,
            handles,
        }
    }

    /// The process-wide pool, created lazily on first use with
    /// `available_parallelism() - 1` workers (the caller is the final
    /// lane).  `TrainContext::new` touches this once so the threads
    /// exist before any hot loop; standalone kernel callers get the
    /// same pool on demand.
    pub fn global() -> &'static ChunkPool {
        static GLOBAL: OnceLock<ChunkPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            ChunkPool::new(cores.saturating_sub(1))
        })
    }

    /// Number of persistent worker threads (the effective parallelism
    /// of a saturating job is `size() + 1`: the submitter participates).
    pub fn size(&self) -> usize {
        self.workers
    }

    /// Run `f(i, seg)` for every chunk `i`, where `seg` is the disjoint
    /// sub-slice `data[bounds[i]..bounds[i + 1]]`.  `bounds` must be
    /// monotone with `bounds[last] <= data.len()`; chunks may be empty.
    /// Blocks until every chunk has executed.  Bit-identical to running
    /// the chunks sequentially in index order, at any pool size.
    pub fn run_chunks<F>(&self, data: &mut [f32], bounds: &[usize], f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let n = bounds.len().saturating_sub(1);
        if n == 0 {
            return;
        }
        assert!(
            bounds.windows(2).all(|w| w[0] <= w[1]),
            "chunk bounds not monotone"
        );
        assert!(
            bounds[n] <= data.len(),
            "chunk bounds exceed the data buffer"
        );
        if n == 1 {
            // single chunk: no fan-out, no erasure
            f(0, &mut data[bounds[0]..bounds[1]]);
            return;
        }
        // Disjointness of `seg` slices follows from monotone bounds;
        // the raw base pointer lets the shared `Fn(usize)` hand each
        // claimer its own `&mut` window.
        let base = SendPtr(data.as_mut_ptr());
        let runner = move |i: usize| {
            let (lo, hi) = (bounds[i], bounds[i + 1]);
            // SAFETY: `bounds` is monotone with `bounds[n] <= data.len()`
            // (asserted above), so `lo..hi` is in bounds and the windows
            // for distinct `i` are disjoint; `data` outlives the job
            // because the submitter blocks until every chunk completes.
            let seg = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
            f(i, seg);
        };
        self.run_erased(n, &runner);
    }

    fn run_erased(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        // nested submission (a chunk kernel calling a pooled kernel)
        // would deadlock on `submit`; run inline instead — same chunk
        // order, same numerics.
        if IN_POOL.with(|c| c.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let _submission = lock_unpoisoned(&self.submit);
        IN_POOL.with(|c| c.set(true));
        let job = Arc::new(Job {
            f: erase(f),
            n,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        {
            let mut slot = lock_unpoisoned(&self.shared.slot);
            slot.gen += 1;
            slot.job = Some(job.clone());
            self.shared.work_cv.notify_all();
        }
        // the submitter is always a lane of its own job
        run_claims(&job);
        {
            let mut slot = lock_unpoisoned(&self.shared.slot);
            while job.completed.load(Ordering::SeqCst) < n {
                slot = self
                    .shared
                    .done_cv
                    .wait(slot)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            slot.job = None; // drop the slot's ref; stale workers hold their own
        }
        IN_POOL.with(|c| c.set(false));
        if job.panicked.load(Ordering::SeqCst) {
            // lint:allow(D002, deliberate re-raise of a caught chunk-kernel panic on the submitting thread per the pool contract)
            panic!("ChunkPool: a chunk kernel panicked (see worker output above)");
        }
    }
}

impl Drop for ChunkPool {
    fn drop(&mut self) {
        {
            let mut slot = lock_unpoisoned(&self.shared.slot);
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Erase the closure's lifetime for the trip through [`Job`].  Sound
/// because the submitter outlives every dereference (module docs).
// the transmute exists solely to erase `'a` — clippy flags
// same-type-modulo-lifetime transmutes as useless
#[allow(clippy::useless_transmute, clippy::unnecessary_cast)]
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> *const (dyn Fn(usize) + Sync) {
    // SAFETY: only the lifetime is transmuted away; the resulting
    // pointer is dereferenced solely between a successful chunk claim
    // and its completion increment, while `run_erased` (holding the
    // `'a` borrow) is still blocked on the completion barrier.
    unsafe {
        std::mem::transmute::<&'a (dyn Fn(usize) + Sync + 'a), &'static (dyn Fn(usize) + Sync)>(f)
    }
}

/// Raw mutable base pointer of the output buffer, shareable across the
/// claiming threads.  Safety: monotone bounds make every derived window
/// disjoint, and the buffer outlives the job (the submitter blocks).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer is the base of the submitter's output buffer;
// every access through it goes to a window derived from monotone chunk
// bounds (disjoint per claimer) while the submitter keeps the buffer
// alive, so shared cross-thread access never aliases a `&mut`.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Claim-and-execute loop shared by workers and the submitter.
///
/// Safety note: the erased closure pointer is turned into a reference
/// only *after* a successful claim (`i < n`) — at that point the
/// submitter is provably still blocked in `run_erased` (it waits for
/// this chunk's `completed` increment), so the pointee is alive.
fn run_claims(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::SeqCst);
        if i >= job.n {
            return;
        }
        // SAFETY: the claim succeeded (`i < n`), so the submitter has not
        // yet seen `completed == n` and is still blocked in `run_erased`
        // with the closure and its captures alive.
        let f = unsafe { &*job.f };
        if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
            job.panicked.store(true, Ordering::SeqCst);
        }
        job.completed.fetch_add(1, Ordering::SeqCst);
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job: Arc<Job> = {
            let mut slot = lock_unpoisoned(&shared.slot);
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.gen != seen {
                    seen = slot.gen;
                    match &slot.job {
                        Some(j) => break j.clone(),
                        // job already finished and was cleared: nothing
                        // to do for this generation
                        None => continue,
                    }
                }
                slot = shared
                    .work_cv
                    .wait(slot)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        IN_POOL.with(|c| c.set(true));
        run_claims(&job);
        IN_POOL.with(|c| c.set(false));
        // wake the submitter if we just finished the last chunk; taking
        // the slot lock orders the notify after its condition check
        if job.completed.load(Ordering::SeqCst) >= job.n {
            let _slot = lock_unpoisoned(&shared.slot);
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sequential oracle for a chunked fill.
    fn fill_seq(data: &mut [f32], bounds: &[usize]) {
        for i in 0..bounds.len() - 1 {
            for (k, v) in data[bounds[i]..bounds[i + 1]].iter_mut().enumerate() {
                *v = (i * 1000 + k) as f32;
            }
        }
    }

    #[test]
    fn executes_every_chunk_exactly_once() {
        for workers in [0usize, 1, 3] {
            let pool = ChunkPool::new(workers);
            let bounds = [0usize, 7, 7, 20, 64];
            let mut want = vec![-1.0f32; 64];
            fill_seq(&mut want, &bounds);
            let mut got = vec![-1.0f32; 64];
            pool.run_chunks(&mut got, &bounds, |i, seg| {
                for (k, v) in seg.iter_mut().enumerate() {
                    *v = (i * 1000 + k) as f32;
                }
            });
            assert_eq!(got, want, "workers = {workers}");
        }
    }

    #[test]
    fn pool_reuse_across_many_jobs() {
        let pool = ChunkPool::new(2);
        let bounds: Vec<usize> = (0..=8).map(|i| i * 5).collect();
        for round in 0..50u32 {
            let mut data = vec![0.0f32; 40];
            pool.run_chunks(&mut data, &bounds, |i, seg| {
                seg.fill(round as f32 + i as f32);
            });
            for i in 0..8 {
                assert!(data[i * 5..(i + 1) * 5]
                    .iter()
                    .all(|&v| v == round as f32 + i as f32));
            }
        }
    }

    #[test]
    fn empty_and_single_chunk_degenerate() {
        let pool = ChunkPool::new(2);
        let mut data = vec![1.0f32; 4];
        pool.run_chunks(&mut data, &[0], |_, _| panic!("no chunks to run"));
        pool.run_chunks(&mut data, &[], |_, _| panic!("no chunks to run"));
        pool.run_chunks(&mut data, &[0, 4], |i, seg| {
            assert_eq!(i, 0);
            seg.fill(2.0);
        });
        assert!(data.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn chunk_panic_is_reraised_and_pool_survives() {
        let pool = ChunkPool::new(2);
        let mut data = vec![0.0f32; 30];
        let bounds: Vec<usize> = (0..=6).map(|i| i * 5).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(&mut data, &bounds, |i, seg| {
                if i == 3 {
                    panic!("kernel bug");
                }
                seg.fill(1.0);
            });
        }));
        assert!(result.is_err(), "panic must re-raise on the submitter");
        // the pool keeps working after a kernel panic
        pool.run_chunks(&mut data, &bounds, |_, seg| seg.fill(9.0));
        assert!(data.iter().all(|&v| v == 9.0));
    }

    #[test]
    fn concurrent_submissions_serialize_correctly() {
        let pool = Arc::new(ChunkPool::new(3));
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let bounds: Vec<usize> = (0..=10).map(|i| i * 11).collect();
                for round in 0..20u32 {
                    let mut data = vec![0.0f32; 110];
                    pool.run_chunks(&mut data, &bounds, |i, seg| {
                        seg.fill((t * 10_000 + round * 100 + i as u32) as f32);
                    });
                    for i in 0..10 {
                        let want = (t * 10_000 + round * 100 + i as u32) as f32;
                        assert!(
                            data[i * 11..(i + 1) * 11].iter().all(|&v| v == want),
                            "thread {t} round {round} chunk {i} corrupted"
                        );
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn nested_submission_runs_inline() {
        let pool = ChunkPool::global();
        let mut outer = vec![0.0f32; 8];
        pool.run_chunks(&mut outer, &[0, 4, 8], |i, seg| {
            // a kernel that (illegally, but survivably) re-enters the
            // pool: must run inline rather than deadlock
            let mut inner = vec![0.0f32; 4];
            ChunkPool::global().run_chunks(&mut inner, &[0, 2, 4], |j, s| {
                s.fill((i * 10 + j) as f32);
            });
            seg.copy_from_slice(&inner);
        });
        assert_eq!(outer, vec![0.0, 0.0, 1.0, 1.0, 10.0, 10.0, 11.0, 11.0]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ChunkPool::new(4);
        let mut data = vec![0.0f32; 16];
        pool.run_chunks(&mut data, &[0, 8, 16], |_, seg| seg.fill(1.0));
        drop(pool); // must not hang
    }
}
