//! Dense row-major f32 matrices — the coordinator-side tensor type.
//!
//! This is deliberately small: the heavy math runs inside the AOT-compiled
//! HLO artifacts (Layers 1-2).  The Rust side needs matrices only for
//! packing literals, the parameter server, the pure-Rust inference oracle
//! ([`crate::gnn`]) and padded propagation-matrix construction
//! ([`crate::halo`]).

pub mod pool;
pub mod sparse;

use crate::util::Rng;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// Glorot-uniform init, matching `init_gcn_params` on the Python side.
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let lim = (6.0 / (rows + cols) as f32).sqrt();
        Matrix::from_fn(rows, cols, |_, _| rng.uniform(-lim, lim))
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn copy_row_from(&mut self, r: usize, src: &[f32]) {
        assert_eq!(src.len(), self.cols);
        self.row_mut(r).copy_from_slice(src);
    }

    /// `self @ other` — naive triple loop with k-inner access pattern
    /// (row-major friendly).  Used by the inference oracle and PS; the
    /// training hot path runs in XLA, not here.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue; // propagation matrices are sparse-ish
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `out = self @ other` without allocating: the blocked kernel used
    /// by the sparse evaluation path.  Output columns are processed in
    /// register-resident blocks so each output element is written once
    /// (the seed [`Matrix::matmul`] reloads and restores the whole
    /// output row on every k step).  Per output element the accumulation
    /// order is still k-ascending, so results match `matmul` except for
    /// entries where `matmul`'s zero-skip elides an exact `+ 0.0`.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert!(
            out.rows == self.rows && out.cols == other.cols,
            "matmul_into out shape mismatch"
        );
        for i in 0..self.rows {
            matmul_row(
                self.row(i),
                &other.data,
                other.cols,
                &mut out.data[i * other.cols..(i + 1) * other.cols],
            );
        }
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// self += alpha * other.  Shapes must match exactly — equal flat
    /// length alone once let a (2,3) accumulate into a (3,2) silently.
    pub fn add_scaled(&mut self, other: &Matrix, alpha: f32) {
        assert!(
            self.rows == other.rows && self.cols == other.cols,
            "add_scaled shape mismatch: {}x{} += {}x{}",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max |a - b| across entries.  Shapes must match exactly (not just
    /// flat length — comparing a (2,3) against a (3,2) is a bug).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert!(
            self.rows == other.rows && self.cols == other.cols,
            "max_abs_diff shape mismatch: {}x{} vs {}x{}",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise argmax (predictions from logits).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0;
                for (i, v) in row.iter().enumerate() {
                    if *v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

/// Column-block width of the dense row kernel: 16 f32 accumulators live
/// in registers across the whole k loop (4×4-wide SSE, or 2×8-wide AVX).
const MM_BLOCK: usize = 16;

/// One output row of `a_row @ b`, column-blocked.  For each block of 16
/// output columns the partial sums stay in a register-resident array
/// across the entire k loop; `b`'s rows stream from cache.  Accumulation
/// over k is in ascending order for every output element regardless of
/// blocking, which is what keeps the threaded matmul bit-deterministic.
pub(crate) fn matmul_row(a_row: &[f32], b: &[f32], b_cols: usize, out_row: &mut [f32]) {
    let mut j = 0;
    while j < b_cols {
        let blk = MM_BLOCK.min(b_cols - j);
        let mut acc = [0f32; MM_BLOCK];
        for (k, &av) in a_row.iter().enumerate() {
            let brow = &b[k * b_cols + j..k * b_cols + j + blk];
            for (a, &bv) in acc[..blk].iter_mut().zip(brow) {
                *a += av * bv;
            }
        }
        out_row[j..j + blk].copy_from_slice(&acc[..blk]);
        j += blk;
    }
}

/// Multithreaded `out = a @ b` on the persistent [`pool::ChunkPool`]:
/// `a`'s rows (and the matching output rows) are split into contiguous
/// chunks, one per requested thread.  Every output row is written by
/// exactly one chunk and the per-element accumulation order is fixed
/// (k-ascending), so the result is **bit-identical at any thread
/// count** — the evaluation-side counterpart of the training engine's
/// determinism guarantee.  (This used to spawn scoped threads per call;
/// the pool removes that per-call spawn/join cost without changing a
/// single output bit.)
pub fn par_matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix, threads: usize) {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    assert!(
        out.rows == a.rows && out.cols == b.cols,
        "par_matmul_into out shape mismatch"
    );
    let threads = threads.clamp(1, a.rows.max(1));
    if threads == 1 || a.cols == 0 || b.cols == 0 {
        return a.matmul_into(b, out);
    }
    let chunk = a.rows.div_ceil(threads);
    let mut row_bounds: Vec<usize> = (0..=threads).map(|i| (i * chunk).min(a.rows)).collect();
    row_bounds.dedup();
    let elem_bounds: Vec<usize> = row_bounds.iter().map(|&r| r * b.cols).collect();
    pool::ChunkPool::global().run_chunks(&mut out.data, &elem_bounds, |i, out_rows| {
        let (lo, hi) = (row_bounds[i], row_bounds[i + 1]);
        for (ar, or) in a.data[lo * a.cols..hi * a.cols]
            .chunks_exact(a.cols)
            .zip(out_rows.chunks_exact_mut(b.cols))
        {
            matmul_row(ar, &b.data, b.cols, or);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_hand_computation() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity_is_noop() {
        let a = Matrix::from_fn(4, 4, |r, c| (r * 4 + c) as f32);
        assert_eq!(a.matmul(&Matrix::eye(4)).data, a.data);
        assert_eq!(Matrix::eye(4).matmul(&a).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 7 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_swaps_matmul_order() {
        let a = Matrix::from_fn(3, 4, |r, c| (r + c) as f32 * 0.5);
        let b = Matrix::from_fn(4, 2, |r, c| (r * 2 + c) as f32 * 0.25);
        let ab_t = a.matmul(&b).transpose();
        let bt_at = b.transpose().matmul(&a.transpose());
        assert!(ab_t.max_abs_diff(&bt_at) < 1e-6);
    }

    #[test]
    fn add_scaled_and_scale() {
        let mut a = Matrix::zeros(2, 2);
        let b = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        a.add_scaled(&b, 2.0);
        assert_eq!(a.data, vec![2., 4., 6., 8.]);
        a.scale(0.5);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn argmax_rows_picks_first_max() {
        let a = Matrix::from_vec(2, 3, vec![0., 5., 5., 9., 1., 2.]);
        assert_eq!(a.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn glorot_within_limits() {
        let mut rng = Rng::new(0);
        let m = Matrix::glorot(30, 20, &mut rng);
        let lim = (6.0f32 / 50.0).sqrt();
        assert!(m.data.iter().all(|v| v.abs() <= lim));
        // not all identical
        assert!(m.data.iter().any(|v| (v - m.data[0]).abs() > 1e-6));
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    #[should_panic(expected = "add_scaled shape mismatch")]
    fn add_scaled_rejects_transposed_shape() {
        // same flat length, different shape: must not accumulate
        let mut a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(3, 2);
        a.add_scaled(&b, 1.0);
    }

    #[test]
    #[should_panic(expected = "max_abs_diff shape mismatch")]
    fn max_abs_diff_rejects_transposed_shape() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(3, 2);
        let _ = a.max_abs_diff(&b);
    }

    #[test]
    fn matmul_into_matches_matmul() {
        let mut rng = Rng::new(13);
        // cols crossing the 16-wide block boundary, incl. exact multiple
        for (m, k, n) in [(3, 5, 4), (7, 11, 16), (5, 9, 17), (4, 2, 33), (1, 1, 1)] {
            let a = Matrix::from_fn(m, k, |_, _| rng.uniform(-1.0, 1.0));
            let b = Matrix::from_fn(k, n, |_, _| rng.uniform(-1.0, 1.0));
            let want = a.matmul(&b);
            let mut got = Matrix::zeros(m, n);
            a.matmul_into(&b, &mut got);
            assert!(got.max_abs_diff(&want) < 1e-6, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn par_matmul_bit_identical_across_threads() {
        let mut rng = Rng::new(17);
        let a = Matrix::from_fn(37, 23, |_, _| rng.uniform(-1.0, 1.0));
        let b = Matrix::from_fn(23, 19, |_, _| rng.uniform(-1.0, 1.0));
        let mut reference = Matrix::zeros(37, 19);
        a.matmul_into(&b, &mut reference);
        for threads in [1, 2, 3, 4, 8, 64] {
            let mut out = Matrix::zeros(37, 19);
            par_matmul_into(&a, &b, &mut out, threads);
            assert!(
                out.data
                    .iter()
                    .zip(&reference.data)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads} diverged"
            );
        }
    }
}
