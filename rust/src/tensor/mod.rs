//! Dense row-major f32 matrices — the coordinator-side tensor type.
//!
//! This is deliberately small: the heavy math runs inside the AOT-compiled
//! HLO artifacts (Layers 1-2).  The Rust side needs matrices only for
//! packing literals, the parameter server, the pure-Rust inference oracle
//! ([`crate::gnn`]) and padded propagation-matrix construction
//! ([`crate::halo`]).

use crate::util::Rng;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// Glorot-uniform init, matching `init_gcn_params` on the Python side.
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let lim = (6.0 / (rows + cols) as f32).sqrt();
        Matrix::from_fn(rows, cols, |_, _| rng.uniform(-lim, lim))
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn copy_row_from(&mut self, r: usize, src: &[f32]) {
        assert_eq!(src.len(), self.cols);
        self.row_mut(r).copy_from_slice(src);
    }

    /// `self @ other` — naive triple loop with k-inner access pattern
    /// (row-major friendly).  Used by the inference oracle and PS; the
    /// training hot path runs in XLA, not here.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue; // propagation matrices are sparse-ish
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// self += alpha * other
    pub fn add_scaled(&mut self, other: &Matrix, alpha: f32) {
        assert_eq!(self.data.len(), other.data.len(), "add_scaled shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max |a - b| across entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise argmax (predictions from logits).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0;
                for (i, v) in row.iter().enumerate() {
                    if *v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_hand_computation() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity_is_noop() {
        let a = Matrix::from_fn(4, 4, |r, c| (r * 4 + c) as f32);
        assert_eq!(a.matmul(&Matrix::eye(4)).data, a.data);
        assert_eq!(Matrix::eye(4).matmul(&a).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 7 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_swaps_matmul_order() {
        let a = Matrix::from_fn(3, 4, |r, c| (r + c) as f32 * 0.5);
        let b = Matrix::from_fn(4, 2, |r, c| (r * 2 + c) as f32 * 0.25);
        let ab_t = a.matmul(&b).transpose();
        let bt_at = b.transpose().matmul(&a.transpose());
        assert!(ab_t.max_abs_diff(&bt_at) < 1e-6);
    }

    #[test]
    fn add_scaled_and_scale() {
        let mut a = Matrix::zeros(2, 2);
        let b = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        a.add_scaled(&b, 2.0);
        assert_eq!(a.data, vec![2., 4., 6., 8.]);
        a.scale(0.5);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn argmax_rows_picks_first_max() {
        let a = Matrix::from_vec(2, 3, vec![0., 5., 5., 9., 1., 2.]);
        assert_eq!(a.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn glorot_within_limits() {
        let mut rng = Rng::new(0);
        let m = Matrix::glorot(30, 20, &mut rng);
        let lim = (6.0f32 / 50.0).sqrt();
        assert!(m.data.iter().all(|v| v.abs() <= lim));
        // not all identical
        assert!(m.data.iter().any(|v| (v - m.data[0]).abs() > 1e-6));
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
