//! Run configuration: every knob of a training run, with JSON loading
//! and CLI-style `key=value` overrides.
//!
//! A downstream user drives the system either from a JSON config file
//! (`digest train --config run.json`) or entirely from flags; the
//! experiment harness builds these programmatically.

use crate::gnn::ModelKind;
use crate::partition::PartitionAlgo;
use crate::ps::optimizer::OptimizerKind;
use crate::util::json::Json;
use crate::{eyre, Result};

/// Training mode (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Synchronous DIGEST (Alg. 1).
    Sync,
    /// Asynchronous DIGEST-A (non-blocking).
    Async,
}

impl std::str::FromStr for Mode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "sync" => Ok(Mode::Sync),
            "async" => Ok(Mode::Async),
            _ => Err(eyre!("unknown mode {s:?} (sync|async)")),
        }
    }
}

/// Which training framework to run (DIGEST vs the baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Digest,
    DigestAsync,
    /// LLCG-like partition-based baseline (edge dropping + global
    /// server correction).
    Llcg,
    /// DGL-like propagation-based baseline (fresh per-epoch exchange).
    Propagation,
    /// Mini-batch neighbor-sampled GraphSAGE training with a
    /// partition-aware remote-neighbor cache (`crate::sample`).
    Sampled,
}

impl Method {
    pub fn as_str(self) -> &'static str {
        match self {
            Method::Digest => "digest",
            Method::DigestAsync => "digest-a",
            Method::Llcg => "llcg",
            Method::Propagation => "dgl",
            Method::Sampled => "sampled",
        }
    }

    /// The full-graph method family the comparison experiments sweep.
    /// `Sampled` is intentionally absent: it requires `model=sage`,
    /// while these sweeps iterate gcn/gat artifacts.
    pub fn all() -> [Method; 4] {
        [Method::Llcg, Method::Propagation, Method::Digest, Method::DigestAsync]
    }
}

impl std::str::FromStr for Method {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "digest" => Ok(Method::Digest),
            "digest-a" | "digest_async" => Ok(Method::DigestAsync),
            "llcg" => Ok(Method::Llcg),
            "dgl" | "propagation" => Ok(Method::Propagation),
            "sampled" => Ok(Method::Sampled),
            _ => Err(eyre!("unknown method {s:?} (digest|digest-a|llcg|dgl|sampled)")),
        }
    }
}

/// What the `ps-serve` daemon does when a worker's connection is lost
/// mid-run (EOF, mid-frame cut, oversize/garbage frame, or read
/// silence past the lease grace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossPolicy {
    /// First-error-wins: any lost worker aborts the whole run (the
    /// pre-lease PR 8 behavior).
    Abort,
    /// Hold the worker's lease and all run state for `loss_grace`
    /// seconds; a reconnecting or freshly re-launched worker resumes
    /// bit-exactly via sequence-numbered reply replay.  The default.
    Wait,
    /// Async (`digest-a`) only: the lost worker departs permanently
    /// and the survivors grind out the remaining update budget.
    Continue,
}

impl LossPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            LossPolicy::Abort => "abort",
            LossPolicy::Wait => "wait",
            LossPolicy::Continue => "continue",
        }
    }

    /// Stable wire tag (the worker's Hello carries its policy so a
    /// daemon/worker disagreement is caught at admission).
    pub fn wire_tag(self) -> u8 {
        match self {
            LossPolicy::Abort => 0,
            LossPolicy::Wait => 1,
            LossPolicy::Continue => 2,
        }
    }

    pub fn from_wire_tag(t: u8) -> Result<Self> {
        match t {
            0 => Ok(LossPolicy::Abort),
            1 => Ok(LossPolicy::Wait),
            2 => Ok(LossPolicy::Continue),
            _ => Err(eyre!("unknown loss-policy wire tag {t}")),
        }
    }
}

impl std::str::FromStr for LossPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "abort" => Ok(LossPolicy::Abort),
            "wait" => Ok(LossPolicy::Wait),
            "continue" => Ok(LossPolicy::Continue),
            _ => Err(eyre!("unknown on_worker_loss {s:?} (abort|wait|continue)")),
        }
    }
}

/// Distributed-transport knobs shared by the `ps-serve` daemon and the
/// socket-backed worker client (`coordinator::dist`).  Flat `key=value`
/// / JSON fields on [`RunConfig`] like everything else, grouped here so
/// both ends agree on one source of truth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DistConfig {
    /// Max seconds a worker waits for one daemon reply before treating
    /// the connection as dead and reconnecting (replaces the old
    /// hardcoded 30 s socket timeout).
    pub io_timeout: f64,
    /// Connection / retransmit attempts before a worker gives up on
    /// the daemon (initial connect and every mid-run reconnect).
    pub connect_retries: usize,
    /// Initial backoff between attempts in milliseconds; doubles per
    /// failure, capped at ~2 s.
    pub backoff_ms: u64,
    /// Daemon-side policy for a lost worker connection.
    pub on_worker_loss: LossPolicy,
    /// Seconds the daemon holds a lost lease (and parks the barriers)
    /// waiting for a rejoin before aborting; `Wait` policy only.
    pub loss_grace: f64,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            io_timeout: 30.0,
            connect_retries: 100,
            backoff_ms: 100,
            on_worker_loss: LossPolicy::Wait,
            loss_grace: 30.0,
        }
    }
}

impl DistConfig {
    fn validate(&self) -> Result<()> {
        if !(self.io_timeout > 0.0 && self.io_timeout.is_finite()) {
            return Err(eyre!("io_timeout must be a finite positive number"));
        }
        if self.connect_retries == 0 {
            return Err(eyre!("connect_retries must be >= 1"));
        }
        if self.backoff_ms == 0 {
            return Err(eyre!("backoff_ms must be >= 1"));
        }
        if self.loss_grace < 0.0 || !self.loss_grace.is_finite() {
            return Err(eyre!("loss_grace must be a finite non-negative number"));
        }
        Ok(())
    }
}

/// Full configuration of a training run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset: String,
    pub model: ModelKind,
    /// Number of partitions / workers (the paper's M).
    pub parts: usize,
    pub partitioner: PartitionAlgo,
    pub method: Method,
    pub epochs: usize,
    /// Representation synchronization interval N (Alg. 1).
    pub sync_interval: usize,
    pub lr: f32,
    pub optimizer: OptimizerKind,
    pub weight_decay: f32,
    /// Overlap pull/push with layer compute (Fig. 2).
    pub overlap: bool,
    /// Evaluate global val/test F1 every `eval_every` epochs.
    pub eval_every: usize,
    /// Worker threads for the parallel execution engine; 0 = auto
    /// (min(parts, available cores)).  Also drives the sparse
    /// global-eval forward (`TrainContext::global_eval`), where 0
    /// resolves to *all* cores and an explicit value caps eval
    /// parallelism too.  Results are bit-identical across thread
    /// counts in both uses — this only trades wall-clock for cores.
    pub threads: usize,
    pub seed: u64,
    /// Straggler injection: worker id + delay range in virtual seconds.
    pub straggler: Option<(usize, f64, f64)>,
    /// Artifact directory (default "artifacts").
    pub artifact_dir: String,
    /// Checkpoint the full training state every K epochs (0 = only at
    /// the end; requires `save_to`).
    pub save_every: usize,
    /// Checkpoint path the driver writes to (periodic + final).
    pub save_to: Option<String>,
    /// Checkpoint path to resume from (`digest train load_from=...`).
    pub load_from: Option<String>,
    /// Early stopping: stop after this many consecutive evaluations
    /// without a val-F1 improvement (0 = off).
    pub early_stop: usize,
    /// Wall-clock budget in real seconds; the driver stops the session
    /// at the first epoch boundary past it (0 = unlimited).
    pub wall_budget: f64,
    /// Stream per-epoch telemetry rows to this CSV file while training
    /// runs (same columns as the post-hoc `--csv` timeline).
    pub stream_csv: Option<String>,
    /// Auto-export the best-val-F1 model (a sealed
    /// `serve::InferenceModel`, `digest-model-v1`) to this path while
    /// training runs; re-written whenever an evaluation sets a new
    /// best (`serve::ExportBestHook`).
    pub export_best: Option<String>,
    /// Delta-encode rep pushes on the socket backend: only rows whose
    /// fingerprint changed since this worker's last push cross the
    /// wire (the daemon reconstructs the full matrix, so training is
    /// still bit-identical to in-memory).  Ignored in-memory.
    pub wire_delta: bool,
    /// Quantize rep-push rows to f16 on the socket backend.  *Lossy*:
    /// breaks bit-identity with the in-memory run (accuracy stays
    /// within epsilon — asserted in tests); off by default.
    pub wire_f16: bool,
    /// Distributed-transport fault-tolerance knobs (socket backend
    /// only; the in-memory backends never look at these).
    pub dist: DistConfig,
    /// Neighbor-sampling fanouts per layer, outermost (layer 0) first —
    /// `method=sampled` only.  Must have `hidden.len() + 1` entries and
    /// no zeros.
    pub fanouts: Vec<usize>,
    /// Mini-batch size (seed nodes per step) — `method=sampled` only.
    pub batch_size: usize,
    /// Per-worker remote-neighbor feature-cache capacity in nodes
    /// (0 disables the cache) — `method=sampled` only.
    pub cache_nodes: usize,
    /// Hidden-layer widths for the sampled SAGE model (the full-graph
    /// methods take widths from their AOT artifact instead).  All
    /// entries must be equal (the artifact spec carries a single d_h).
    pub hidden: Vec<usize>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "karate".into(),
            model: ModelKind::Gcn,
            parts: 2,
            partitioner: PartitionAlgo::Metis,
            method: Method::Digest,
            epochs: 100,
            sync_interval: 10,
            lr: 0.01,
            optimizer: OptimizerKind::Adam,
            weight_decay: 0.0,
            overlap: true,
            eval_every: 5,
            threads: 0,
            seed: 42,
            straggler: None,
            artifact_dir: "artifacts".into(),
            save_every: 0,
            save_to: None,
            load_from: None,
            early_stop: 0,
            wall_budget: 0.0,
            stream_csv: None,
            export_best: None,
            wire_delta: true,
            wire_f16: false,
            dist: DistConfig::default(),
            fanouts: vec![10, 25],
            batch_size: 32,
            cache_nodes: 1024,
            hidden: vec![16],
        }
    }
}

/// Parse a comma-separated usize list (`fanouts=10,25`); empty or
/// non-numeric entries are structured errors.
fn parse_usize_list(k: &str, v: &str) -> Result<Vec<usize>> {
    v.split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|e| eyre!("{k}: entry {s:?}: {e}"))
        })
        .collect()
}

impl RunConfig {
    /// Parse from a JSON object (all fields optional, defaults apply).
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = RunConfig::default();
        if let Some(v) = j.opt("dataset") {
            c.dataset = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("model") {
            c.model = v.as_str()?.parse()?;
        }
        if let Some(v) = j.opt("parts") {
            c.parts = v.as_usize()?;
        }
        if let Some(v) = j.opt("partitioner") {
            c.partitioner = v.as_str()?.parse()?;
        }
        if let Some(v) = j.opt("method") {
            c.method = v.as_str()?.parse()?;
        }
        if let Some(v) = j.opt("epochs") {
            c.epochs = v.as_usize()?;
        }
        if let Some(v) = j.opt("sync_interval") {
            c.sync_interval = v.as_usize()?;
        }
        if let Some(v) = j.opt("lr") {
            c.lr = v.as_f64()? as f32;
        }
        if let Some(v) = j.opt("optimizer") {
            c.optimizer = v.as_str()?.parse()?;
        }
        if let Some(v) = j.opt("weight_decay") {
            c.weight_decay = v.as_f64()? as f32;
        }
        if let Some(v) = j.opt("overlap") {
            c.overlap = v.as_bool()?;
        }
        if let Some(v) = j.opt("eval_every") {
            c.eval_every = v.as_usize()?;
        }
        if let Some(v) = j.opt("threads") {
            c.threads = v.as_usize()?;
        }
        if let Some(v) = j.opt("seed") {
            // exact u64 parse: seeds above 2^53 used to round silently
            c.seed = v.as_u64()?;
        }
        if let Some(v) = j.opt("artifact_dir") {
            c.artifact_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("save_every") {
            c.save_every = v.as_usize()?;
        }
        if let Some(v) = j.opt("save_to") {
            c.save_to = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.opt("load_from") {
            c.load_from = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.opt("early_stop") {
            c.early_stop = v.as_usize()?;
        }
        if let Some(v) = j.opt("wall_budget") {
            c.wall_budget = v.as_f64()?;
        }
        if let Some(v) = j.opt("stream_csv") {
            c.stream_csv = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.opt("export_best") {
            c.export_best = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.opt("wire_delta") {
            c.wire_delta = v.as_bool()?;
        }
        if let Some(v) = j.opt("wire_f16") {
            c.wire_f16 = v.as_bool()?;
        }
        if let Some(v) = j.opt("io_timeout") {
            c.dist.io_timeout = v.as_f64()?;
        }
        if let Some(v) = j.opt("connect_retries") {
            c.dist.connect_retries = v.as_usize()?;
        }
        if let Some(v) = j.opt("backoff_ms") {
            c.dist.backoff_ms = v.as_u64()?;
        }
        if let Some(v) = j.opt("on_worker_loss") {
            c.dist.on_worker_loss = v.as_str()?.parse()?;
        }
        if let Some(v) = j.opt("loss_grace") {
            c.dist.loss_grace = v.as_f64()?;
        }
        if let Some(v) = j.opt("straggler") {
            let arr = v.as_arr()?;
            if arr.len() != 3 {
                return Err(eyre!("straggler must be [worker, lo, hi]"));
            }
            c.straggler = Some((arr[0].as_usize()?, arr[1].as_f64()?, arr[2].as_f64()?));
        }
        if let Some(v) = j.opt("fanouts") {
            c.fanouts = v.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?;
        }
        if let Some(v) = j.opt("batch_size") {
            c.batch_size = v.as_usize()?;
        }
        if let Some(v) = j.opt("cache_nodes") {
            c.cache_nodes = v.as_usize()?;
        }
        if let Some(v) = j.opt("hidden") {
            c.hidden = v.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?;
        }
        c.validate()?;
        Ok(c)
    }

    /// Apply one `key=value` override (CLI).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| eyre!("override {kv:?} must be key=value"))?;
        match k {
            "dataset" => self.dataset = v.to_string(),
            "model" => self.model = v.parse()?,
            "parts" => self.parts = v.parse().map_err(|e| eyre!("parts: {e}"))?,
            "partitioner" => self.partitioner = v.parse()?,
            "method" => self.method = v.parse()?,
            "epochs" => self.epochs = v.parse().map_err(|e| eyre!("epochs: {e}"))?,
            "sync_interval" => {
                self.sync_interval = v.parse().map_err(|e| eyre!("sync_interval: {e}"))?
            }
            "lr" => self.lr = v.parse().map_err(|e| eyre!("lr: {e}"))?,
            "optimizer" => self.optimizer = v.parse()?,
            "weight_decay" => {
                self.weight_decay = v.parse().map_err(|e| eyre!("weight_decay: {e}"))?
            }
            "overlap" => self.overlap = v.parse().map_err(|e| eyre!("overlap: {e}"))?,
            "eval_every" => {
                self.eval_every = v.parse().map_err(|e| eyre!("eval_every: {e}"))?
            }
            "threads" => self.threads = v.parse().map_err(|e| eyre!("threads: {e}"))?,
            "seed" => self.seed = v.parse().map_err(|e| eyre!("seed: {e}"))?,
            "artifact_dir" => self.artifact_dir = v.to_string(),
            "save_every" => {
                self.save_every = v.parse().map_err(|e| eyre!("save_every: {e}"))?
            }
            "save_to" => self.save_to = Some(v.to_string()),
            "load_from" => self.load_from = Some(v.to_string()),
            "early_stop" => {
                self.early_stop = v.parse().map_err(|e| eyre!("early_stop: {e}"))?
            }
            "wall_budget" => {
                self.wall_budget = v.parse().map_err(|e| eyre!("wall_budget: {e}"))?
            }
            "stream_csv" => self.stream_csv = Some(v.to_string()),
            "export_best" => self.export_best = Some(v.to_string()),
            "wire_delta" => {
                self.wire_delta = v.parse().map_err(|e| eyre!("wire_delta: {e}"))?
            }
            "wire_f16" => self.wire_f16 = v.parse().map_err(|e| eyre!("wire_f16: {e}"))?,
            "io_timeout" => {
                self.dist.io_timeout = v.parse().map_err(|e| eyre!("io_timeout: {e}"))?
            }
            "connect_retries" => {
                self.dist.connect_retries =
                    v.parse().map_err(|e| eyre!("connect_retries: {e}"))?
            }
            "backoff_ms" => {
                self.dist.backoff_ms = v.parse().map_err(|e| eyre!("backoff_ms: {e}"))?
            }
            "on_worker_loss" => self.dist.on_worker_loss = v.parse()?,
            "loss_grace" => {
                self.dist.loss_grace = v.parse().map_err(|e| eyre!("loss_grace: {e}"))?
            }
            "fanouts" => self.fanouts = parse_usize_list("fanouts", v)?,
            "batch_size" => {
                self.batch_size = v.parse().map_err(|e| eyre!("batch_size: {e}"))?
            }
            "cache_nodes" => {
                self.cache_nodes = v.parse().map_err(|e| eyre!("cache_nodes: {e}"))?
            }
            "hidden" => self.hidden = parse_usize_list("hidden", v)?,
            _ => return Err(eyre!("unknown config key {k:?}")),
        }
        // field-local rules only: cross-field constraints (straggler id
        // vs parts, save_every vs save_to) are deferred to the full
        // `validate()` at load/run time, so `save_every=10 save_to=x`
        // works in either argument order
        self.validate_fields()
    }

    /// Full validation: every field-local rule plus the cross-field
    /// constraints.  Runs on JSON load and at `TrainContext::new`.
    pub fn validate(&self) -> Result<()> {
        self.validate_fields()?;
        // catch a bad straggler worker id here instead of deep inside
        // the scheduler (where it used to surface as an index panic)
        if let Some((w, _, _)) = self.straggler {
            if w >= self.parts {
                return Err(eyre!(
                    "straggler worker {w} out of range (parts = {})",
                    self.parts
                ));
            }
        }
        if self.save_every > 0 && self.save_to.is_none() {
            return Err(eyre!("save_every requires save_to"));
        }
        // `continue` shrinks the membership and keeps training, which
        // is only sound for the barrier-free async scheduler: a sync
        // round can never fill without every partition's submit
        if self.dist.on_worker_loss == LossPolicy::Continue
            && self.method != Method::DigestAsync
        {
            return Err(eyre!(
                "on_worker_loss=continue requires method=digest-a \
                 (sync barriers cannot shrink; use abort or wait)"
            ));
        }
        // sampled training is the SAGE mini-batch path and nothing else:
        // the fanout block structure only matches the mean-aggregator
        // forward, and the full-graph methods have no sampler
        if self.method == Method::Sampled && self.model != ModelKind::Sage {
            return Err(eyre!(
                "method=sampled requires model=sage (got model={})",
                self.model.as_str()
            ));
        }
        if self.model == ModelKind::Sage && self.method != Method::Sampled {
            return Err(eyre!(
                "model=sage requires method=sampled (got method={}); \
                 no AOT artifacts exist for SAGE",
                self.method.as_str()
            ));
        }
        if self.method == Method::Sampled {
            if self.fanouts.len() != self.hidden.len() + 1 {
                return Err(eyre!(
                    "fanouts must have one entry per layer: {} fanouts vs {} layers \
                     (hidden.len() + 1)",
                    self.fanouts.len(),
                    self.hidden.len() + 1
                ));
            }
            if self.hidden.windows(2).any(|w| w[0] != w[1]) {
                return Err(eyre!(
                    "hidden widths must all be equal (the artifact spec carries a \
                     single d_h); got {:?}",
                    self.hidden
                ));
            }
        }
        Ok(())
    }

    fn validate_fields(&self) -> Result<()> {
        if self.parts == 0 {
            return Err(eyre!("parts must be >= 1"));
        }
        // the schedulers compute `r % sync_interval` / `r % eval_every`
        // every epoch — reject 0 here with a clear message instead of a
        // divide-by-zero panic deep inside the training loop
        if self.sync_interval == 0 {
            return Err(eyre!("sync_interval must be >= 1"));
        }
        if self.eval_every == 0 {
            return Err(eyre!("eval_every must be >= 1"));
        }
        if self.epochs == 0 {
            return Err(eyre!("epochs must be >= 1"));
        }
        if !(self.lr > 0.0) {
            return Err(eyre!("lr must be positive"));
        }
        if let Some((_, lo, hi)) = self.straggler {
            if !(lo.is_finite() && hi.is_finite() && 0.0 <= lo && lo <= hi) {
                return Err(eyre!("straggler delay range [{lo}, {hi}] invalid"));
            }
        }
        if self.wall_budget < 0.0 || !self.wall_budget.is_finite() {
            return Err(eyre!("wall_budget must be a finite non-negative number"));
        }
        // sampler knobs: the block builder computes `ceil(n / batch_size)`
        // and sizes per-layer scratch from fanouts — reject the degenerate
        // values here with a clear message instead of a panic mid-epoch
        if self.batch_size == 0 {
            return Err(eyre!("batch_size must be >= 1"));
        }
        if self.fanouts.is_empty() {
            return Err(eyre!("fanouts must not be empty"));
        }
        if self.fanouts.contains(&0) {
            return Err(eyre!(
                "fanouts must not contain 0 (got {:?}); a zero fanout samples \
                 no neighbors and degenerates the layer",
                self.fanouts
            ));
        }
        if self.hidden.contains(&0) {
            return Err(eyre!("hidden widths must be >= 1 (got {:?})", self.hidden));
        }
        self.dist.validate()?;
        Ok(())
    }

    /// The artifact name this run needs (e.g. "arxiv_s_gcn").
    pub fn artifact_name(&self) -> Result<String> {
        let spec = crate::graph::registry::spec(&self.dataset)?;
        Ok(format!("{}_{}", spec.artifact, self.model.as_str()))
    }
}

/// Configuration for the `digest serve` daemon (`serve::net::Server`).
/// Built from CLI flags in `main.rs`; `validate()` runs at
/// `Server::bind`, so a bad config is a structured startup error, not
/// a panic in the accept loop.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; `127.0.0.1:0` = ephemeral port (tests read the
    /// bound address back via `Server::local_addr`).
    pub addr: String,
    /// Connection-handler cap: connection `max_conns + 1` gets a
    /// structured `Busy` frame (explicit backpressure, never a hang).
    pub max_conns: usize,
    /// Hot-rollover watch file (the training side's `export_best=`
    /// target); None disables rollover.
    pub watch: Option<String>,
    /// Watch-file poll interval in milliseconds.
    pub poll_ms: u64,
    /// Engine thread count (0 = auto), forwarded to
    /// `InferenceEngine::with_threads`.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7411".to_string(),
            max_conns: 64,
            watch: None,
            poll_ms: 200,
            threads: 0,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.addr.is_empty() {
            return Err(eyre!("serve addr must not be empty"));
        }
        if self.max_conns == 0 {
            return Err(eyre!("max_conns must be >= 1"));
        }
        if self.poll_ms == 0 {
            // the accept loop computes `elapsed >= poll_ms` each idle
            // tick; 0 would busy-spin the watch stat() call
            return Err(eyre!("poll_ms must be >= 1"));
        }
        if let Some(w) = &self.watch {
            if w.is_empty() {
                return Err(eyre!("watch path must not be empty"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn from_json_full() {
        let j = Json::parse(
            r#"{
                "dataset": "arxiv-s", "model": "gat", "parts": 4,
                "partitioner": "bfs", "method": "digest-a", "epochs": 50,
                "sync_interval": 5, "lr": 0.005, "optimizer": "sgd",
                "overlap": false, "eval_every": 10, "seed": 7,
                "straggler": [1, 8.0, 10.0]
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.dataset, "arxiv-s");
        assert_eq!(c.model, ModelKind::Gat);
        assert_eq!(c.parts, 4);
        assert_eq!(c.partitioner, PartitionAlgo::Bfs);
        assert_eq!(c.method, Method::DigestAsync);
        assert_eq!(c.sync_interval, 5);
        assert_eq!(c.optimizer, OptimizerKind::Sgd);
        assert!(!c.overlap);
        assert_eq!(c.straggler, Some((1, 8.0, 10.0)));
    }

    #[test]
    fn from_json_partial_uses_defaults() {
        let j = Json::parse(r#"{"dataset": "karate"}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.epochs, RunConfig::default().epochs);
    }

    #[test]
    fn overrides_apply_and_validate() {
        let mut c = RunConfig::default();
        c.apply_override("epochs=10").unwrap();
        c.apply_override("method=llcg").unwrap();
        assert_eq!(c.epochs, 10);
        assert_eq!(c.method, Method::Llcg);
        assert!(c.apply_override("epochs=0").is_err());
        assert!(c.apply_override("bogus=1").is_err());
        assert!(c.apply_override("noequals").is_err());
    }

    #[test]
    fn wire_knobs_parse_and_default() {
        let c = RunConfig::default();
        assert!(c.wire_delta, "delta encoding is the lossless default");
        assert!(!c.wire_f16, "lossy quantization must be opt-in");
        let j = Json::parse(r#"{"wire_delta": false, "wire_f16": true}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(!c.wire_delta);
        assert!(c.wire_f16);
        let mut c = RunConfig::default();
        c.apply_override("wire_delta=false").unwrap();
        c.apply_override("wire_f16=true").unwrap();
        assert!(!c.wire_delta && c.wire_f16);
        assert!(c.apply_override("wire_f16=maybe").is_err());
    }

    #[test]
    fn artifact_name_resolution() {
        let mut c = RunConfig::default();
        c.dataset = "products-s".into();
        c.model = ModelKind::Gat;
        assert_eq!(c.artifact_name().unwrap(), "products_s_gat");
    }

    #[test]
    fn bad_json_values_rejected() {
        let j = Json::parse(r#"{"parts": 0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "rnn"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn zero_intervals_are_validation_errors_not_panics() {
        let mut c = RunConfig::default();
        c.sync_interval = 0;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("sync_interval"), "{err}");
        c.sync_interval = 1;
        c.eval_every = 0;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("eval_every"), "{err}");
        // and through the JSON path too
        let j = Json::parse(r#"{"eval_every": 0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"sync_interval": 0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn large_seed_parses_exactly_from_json() {
        // 0x9E3779B97F4A7C15 has low bits set above 2^53: the old
        // as_f64()-based parse silently rounded it to a different seed
        let seed = 0x9E3779B97F4A7C15u64;
        let j = Json::parse(&format!("{{\"seed\": {seed}}}")).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.seed, seed);
        // 2^53 + 1 is the smallest lossy integer
        let j = Json::parse(r#"{"seed": 9007199254740993}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().seed, 9007199254740993);
        // non-integer seeds are config errors, not silent truncations
        let j = Json::parse(r#"{"seed": 1.5}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn straggler_worker_id_validated_against_parts() {
        let mut c = RunConfig::default();
        c.parts = 2;
        c.straggler = Some((2, 1.0, 2.0));
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("straggler worker 2"), "{err}");
        c.straggler = Some((1, 1.0, 2.0));
        c.validate().unwrap();
        // inverted or negative delay ranges are rejected too
        c.straggler = Some((0, 5.0, 2.0));
        assert!(c.validate().is_err());
        c.straggler = Some((0, -1.0, 2.0));
        assert!(c.validate().is_err());
        // and through the JSON path
        let j = Json::parse(r#"{"parts": 2, "straggler": [3, 1.0, 2.0]}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn session_knobs_parse_and_validate() {
        let j = Json::parse(
            r#"{
                "save_every": 5, "save_to": "ck.json",
                "early_stop": 3, "wall_budget": 120.5,
                "stream_csv": "live.csv"
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.save_every, 5);
        assert_eq!(c.save_to.as_deref(), Some("ck.json"));
        assert_eq!(c.early_stop, 3);
        assert!((c.wall_budget - 120.5).abs() < 1e-12);
        assert_eq!(c.stream_csv.as_deref(), Some("live.csv"));
        // the export_best knob rides the same paths
        let j = Json::parse(r#"{"export_best": "best.model.json"}"#).unwrap();
        assert_eq!(
            RunConfig::from_json(&j).unwrap().export_best.as_deref(),
            Some("best.model.json")
        );
        let mut c2 = RunConfig::default();
        c2.apply_override("export_best=m.json").unwrap();
        assert_eq!(c2.export_best.as_deref(), Some("m.json"));
        // save_every without a path is a config error
        let j = Json::parse(r#"{"save_every": 5}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        // CLI overrides hit the same fields, in EITHER order (cross-field
        // constraints are deferred to the full validate at run time)
        let mut c = RunConfig::default();
        c.apply_override("save_every=2").unwrap();
        c.apply_override("save_to=out.json").unwrap();
        c.apply_override("early_stop=4").unwrap();
        c.apply_override("load_from=in.json").unwrap();
        c.validate().unwrap();
        assert_eq!(c.save_every, 2);
        assert_eq!(c.load_from.as_deref(), Some("in.json"));
        assert!(c.apply_override("wall_budget=-1").is_err());
        // but a config left with save_every and no path fails the full check
        let mut dangling = RunConfig::default();
        dangling.apply_override("save_every=2").unwrap();
        assert!(dangling.validate().is_err());
    }

    #[test]
    fn threads_knob_parses_and_defaults_to_auto() {
        assert_eq!(RunConfig::default().threads, 0);
        let j = Json::parse(r#"{"threads": 4}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().threads, 4);
        let mut c = RunConfig::default();
        c.apply_override("threads=2").unwrap();
        assert_eq!(c.threads, 2);
    }

    #[test]
    fn dist_knobs_parse_and_default() {
        let c = RunConfig::default();
        assert!((c.dist.io_timeout - 30.0).abs() < 1e-12);
        assert_eq!(c.dist.connect_retries, 100);
        assert_eq!(c.dist.backoff_ms, 100);
        assert_eq!(c.dist.on_worker_loss, LossPolicy::Wait);
        assert!((c.dist.loss_grace - 30.0).abs() < 1e-12);
        let j = Json::parse(
            r#"{
                "method": "digest-a", "io_timeout": 2.5,
                "connect_retries": 7, "backoff_ms": 10,
                "on_worker_loss": "continue", "loss_grace": 5.0
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!((c.dist.io_timeout - 2.5).abs() < 1e-12);
        assert_eq!(c.dist.connect_retries, 7);
        assert_eq!(c.dist.backoff_ms, 10);
        assert_eq!(c.dist.on_worker_loss, LossPolicy::Continue);
        assert!((c.dist.loss_grace - 5.0).abs() < 1e-12);
        // CLI overrides hit the same fields
        let mut c = RunConfig::default();
        c.apply_override("io_timeout=1.5").unwrap();
        c.apply_override("connect_retries=3").unwrap();
        c.apply_override("backoff_ms=20").unwrap();
        c.apply_override("on_worker_loss=abort").unwrap();
        c.apply_override("loss_grace=0").unwrap();
        assert!((c.dist.io_timeout - 1.5).abs() < 1e-12);
        assert_eq!(c.dist.connect_retries, 3);
        assert_eq!(c.dist.on_worker_loss, LossPolicy::Abort);
        assert!(c.apply_override("on_worker_loss=maybe").is_err());
        assert!(c.apply_override("io_timeout=0").is_err());
        assert!(c.apply_override("connect_retries=0").is_err());
        assert!(c.apply_override("backoff_ms=0").is_err());
        assert!(c.apply_override("loss_grace=-1").is_err());
    }

    #[test]
    fn continue_policy_requires_async_method() {
        // field-locally fine in either override order; the cross-field
        // rule fires at full validate
        let mut c = RunConfig::default();
        c.apply_override("on_worker_loss=continue").unwrap();
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("digest-a"), "{err}");
        c.apply_override("method=digest-a").unwrap();
        c.validate().unwrap();
        // and through the JSON path (validate runs at load)
        let j = Json::parse(r#"{"on_worker_loss": "continue"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j =
            Json::parse(r#"{"method": "digest-a", "on_worker_loss": "continue"}"#).unwrap();
        RunConfig::from_json(&j).unwrap();
    }

    #[test]
    fn sample_knobs_parse_and_default() {
        let c = RunConfig::default();
        assert_eq!(c.fanouts, vec![10, 25]);
        assert_eq!(c.batch_size, 32);
        assert_eq!(c.cache_nodes, 1024);
        assert_eq!(c.hidden, vec![16]);
        let j = Json::parse(
            r#"{
                "method": "sampled", "model": "sage",
                "fanouts": [5, 5, 10], "batch_size": 8,
                "cache_nodes": 0, "hidden": [32, 32]
            }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.method, Method::Sampled);
        assert_eq!(c.model, ModelKind::Sage);
        assert_eq!(c.fanouts, vec![5, 5, 10]);
        assert_eq!(c.batch_size, 8);
        assert_eq!(c.cache_nodes, 0);
        assert_eq!(c.hidden, vec![32, 32]);
        // CLI overrides hit the same fields; lists are comma-separated
        let mut c = RunConfig::default();
        c.apply_override("fanouts=3,7").unwrap();
        c.apply_override("hidden=8").unwrap();
        c.apply_override("batch_size=4").unwrap();
        c.apply_override("cache_nodes=64").unwrap();
        assert_eq!(c.fanouts, vec![3, 7]);
        assert_eq!(c.hidden, vec![8]);
        assert_eq!(c.batch_size, 4);
        assert_eq!(c.cache_nodes, 64);
        assert!(c.apply_override("fanouts=3,x").is_err());
        assert!(c.apply_override("fanouts=").is_err());
    }

    #[test]
    fn zero_sample_knobs_are_validation_errors_not_panics() {
        // same pattern as sync_interval/eval_every: degenerate values
        // must surface as structured Errs at parse time
        let mut c = RunConfig::default();
        c.batch_size = 0;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("batch_size"), "{err}");
        let mut c = RunConfig::default();
        c.fanouts = vec![10, 0];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("fanouts"), "{err}");
        let mut c = RunConfig::default();
        c.fanouts = vec![];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("fanouts"), "{err}");
        let mut c = RunConfig::default();
        c.hidden = vec![0];
        assert!(c.validate().is_err());
        // field-local rules fire on override too, and through JSON
        let mut c = RunConfig::default();
        assert!(c.apply_override("batch_size=0").is_err());
        assert!(c.apply_override("fanouts=0,10").is_err());
        let j = Json::parse(r#"{"batch_size": 0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"fanouts": [0, 10]}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"fanouts": []}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn sampled_method_and_sage_model_imply_each_other() {
        // sampled without sage
        let j = Json::parse(r#"{"method": "sampled"}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("model=sage"), "{err}");
        // sage without sampled
        let j = Json::parse(r#"{"model": "sage"}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("method=sampled"), "{err}");
        // together they validate
        let j = Json::parse(r#"{"method": "sampled", "model": "sage"}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.method.as_str(), "sampled");
        // fanout/layer count mismatch is a cross-field error
        let mut c = RunConfig::default();
        c.method = Method::Sampled;
        c.model = ModelKind::Sage;
        c.fanouts = vec![10];
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("one entry per layer"), "{err}");
        c.fanouts = vec![10, 25];
        c.validate().unwrap();
        // non-uniform hidden widths are rejected for sampled runs
        c.hidden = vec![16, 32];
        c.fanouts = vec![5, 5, 5];
        assert!(c.validate().is_err());
        // Method::all() stays the full-graph family: the comparison
        // sweeps iterate it with gcn/gat artifacts
        assert!(!Method::all().contains(&Method::Sampled));
        assert_eq!("sampled".parse::<Method>().unwrap(), Method::Sampled);
    }

    #[test]
    fn loss_policy_wire_tags_round_trip() {
        for p in [LossPolicy::Abort, LossPolicy::Wait, LossPolicy::Continue] {
            assert_eq!(LossPolicy::from_wire_tag(p.wire_tag()).unwrap(), p);
            assert_eq!(p.as_str().parse::<LossPolicy>().unwrap(), p);
        }
        assert!(LossPolicy::from_wire_tag(9).is_err());
    }

    #[test]
    fn serve_config_defaults_validate() {
        let c = ServeConfig::default();
        c.validate().unwrap();
        assert_eq!(c.max_conns, 64);
        assert!(c.watch.is_none());
    }

    #[test]
    fn serve_config_rejects_degenerate_values() {
        let mut c = ServeConfig::default();
        c.max_conns = 0;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.poll_ms = 0;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.addr = String::new();
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.watch = Some(String::new());
        assert!(c.validate().is_err());
    }
}
