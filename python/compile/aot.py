"""AOT compilation driver: lower every artifact config to HLO **text**.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the Rust ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py and README of that reference).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Options:
  --only NAME[,NAME...]   lower a subset of configs
  --kinds train,eval      which step kinds to emit (default both)

Emits ``<name>_{train,eval}.hlo.txt`` plus ``manifest.json`` describing
every artifact's exact input/output ordering, shapes and dtypes — the
ABI contract consumed by ``rust/src/runtime/manifest.rs``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, ArtifactConfig
from .train_step import flat_args, make_eval_step, make_train_step

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: ArtifactConfig, kind: str) -> str:
    step = make_train_step(cfg) if kind == "train" else make_eval_step(cfg)
    lowered = jax.jit(step).lower(*flat_args(cfg, kind))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated config names")
    ap.add_argument("--kinds", default="train,eval")
    ap.add_argument(
        "--backend", default="", choices=["", "pallas", "xla"],
        help="kernel backend for the emitted artifacts (default: pallas, "
             "or DIGEST_KERNEL_BACKEND)",
    )
    # kept for Makefile compatibility; ignored in favour of --out-dir
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or out_dir
    os.makedirs(out_dir, exist_ok=True)

    if args.backend:
        from .kernels.aggregate import set_backend

        set_backend(args.backend)
    only = {n for n in args.only.split(",") if n}
    kinds = [k for k in args.kinds.split(",") if k]
    configs = [c for c in CONFIGS if not only or c.name in only]

    manifest = {"version": MANIFEST_VERSION, "artifacts": []}
    for cfg in configs:
        for kind in kinds:
            t0 = time.time()
            text = lower_config(cfg, kind)
            fname = f"{cfg.name}_{kind}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(cfg.to_manifest(kind, fname))
            print(
                f"lowered {cfg.name:>16s} {kind:5s} -> {fname:32s} "
                f"({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)"
            )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
