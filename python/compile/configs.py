"""Artifact manifest: the named, shape-specialized AOT configurations.

HLO executables have static shapes, so each artifact fixes

    (model, L, S_pad, B_pad, d_in, d_h, n_class, act, normalize)

and the Rust coordinator pads every subgraph batch to the artifact it
selects (see ``rust/src/halo``).  Dataset-scale configs mirror the
paper's four benchmarks at CI scale (DESIGN.md §2 documents the
substitution); `karate` is the tiny sanity config used by unit tests
and the quickstart example.

The input/output *ordering* emitted into ``artifacts/manifest.json`` is
the binding contract with ``rust/src/runtime`` — change it only in
lockstep with the Rust side.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ArtifactConfig:
    name: str
    model: str  # "gcn" | "gat"
    layers: int
    s_pad: int  # padded in-subgraph node count
    b_pad: int  # padded halo (out-of-subgraph) node count
    d_in: int
    d_h: int
    n_class: int
    act: str = ""  # "" -> model default (relu for gcn, elu for gat)
    normalize: bool = False  # row-L2 normalization (Alg. 1 line 11)

    def activation(self) -> str:
        return self.act or ("relu" if self.model == "gcn" else "elu")

    def dims(self) -> List[int]:
        return [self.d_in] + [self.d_h] * (self.layers - 1) + [self.n_class]

    def param_keys(self) -> List[str]:
        """Flattening order of per-layer params (contract with Rust)."""
        return ["w", "b"] if self.model == "gcn" else ["w", "b", "a_src", "a_dst"]

    def input_specs(self, kind: str = "train") -> List[Tuple[str, Tuple[int, ...], str]]:
        """[(name, shape, dtype)] in positional order.

        Eval steps omit y/mask: XLA dead-code-eliminates unused entry
        parameters, so structurally-unused inputs must not be in the
        signature at all (or the Rust side would over-supply buffers).
        """
        specs: List[Tuple[str, Tuple[int, ...], str]] = [
            ("x", (self.s_pad + self.b_pad, self.d_in), "f32"),
            ("p_in", (self.s_pad, self.s_pad), "f32"),
            ("p_out", (self.s_pad, self.b_pad), "f32"),
        ]
        for l in range(self.layers - 1):
            specs.append((f"h_stale_{l}", (self.b_pad, self.d_h), "f32"))
        dims = self.dims()
        for l in range(self.layers):
            for key in self.param_keys():
                if key == "w":
                    shape: Tuple[int, ...] = (dims[l], dims[l + 1])
                else:  # b, a_src, a_dst all have the layer output dim
                    shape = (dims[l + 1],)
                specs.append((f"l{l}_{key}", shape, "f32"))
        if kind == "train":
            specs.append(("y", (self.s_pad,), "i32"))
            specs.append(("mask", (self.s_pad,), "f32"))
        return specs

    def output_specs(self, kind: str) -> List[Tuple[str, Tuple[int, ...], str]]:
        """Train: loss, ncorrect, logits, fresh reps, grads. Eval: logits, reps."""
        logits = ("logits", (self.s_pad, self.n_class), "f32")
        reps = [
            (f"rep_{l}", (self.s_pad, self.d_h), "f32")
            for l in range(self.layers - 1)
        ]
        if kind == "eval":
            return [logits] + reps
        specs = [("loss", (), "f32"), ("ncorrect", (), "f32"), logits] + reps
        dims = self.dims()
        for l in range(self.layers):
            for key in self.param_keys():
                if key == "w":
                    shape: Tuple[int, ...] = (dims[l], dims[l + 1])
                else:
                    shape = (dims[l + 1],)
                specs.append((f"grad_l{l}_{key}", shape, "f32"))
        return specs

    def to_manifest(self, kind: str, filename: str) -> Dict:
        d = asdict(self)
        d["act"] = self.activation()
        d["kind"] = kind
        d["file"] = filename
        d["inputs"] = [
            {"name": n, "shape": list(s), "dtype": t}
            for n, s, t in self.input_specs(kind)
        ]
        d["outputs"] = [
            {"name": n, "shape": list(s), "dtype": t}
            for n, s, t in self.output_specs(kind)
        ]
        return d


def _pair(name: str, **kw) -> List[ArtifactConfig]:
    """A gcn + gat config pair sharing shapes."""
    return [
        ArtifactConfig(name=f"{name}_gcn", model="gcn", **kw),
        ArtifactConfig(name=f"{name}_gat", model="gat", **kw),
    ]


#: All configs lowered by `make artifacts`.  Dataset-scale shapes assume
#: M=4 partitions of the CI-scale synthetic datasets (DESIGN.md §2);
#: B_pad is sized from measured halo ratios (Fig. 9) with ~1.5x slack.
CONFIGS: List[ArtifactConfig] = (
    _pair("karate", layers=2, s_pad=32, b_pad=32, d_in=16, d_h=16, n_class=4)
    + _pair("arxiv_s", layers=2, s_pad=512, b_pad=1024, d_in=128, d_h=64, n_class=40)
    + _pair("flickr_s", layers=2, s_pad=256, b_pad=768, d_in=200, d_h=64, n_class=7)
    + _pair("reddit_s", layers=2, s_pad=256, b_pad=768, d_in=300, d_h=64, n_class=41)
    + _pair(
        "products_s", layers=2, s_pad=1024, b_pad=1024, d_in=100, d_h=64, n_class=47
    )
    # depth ablation: 3-layer GCN (two stale tensors / two pushed reps)
    + [
        ArtifactConfig(
            name="arxiv_s_l3_gcn",
            model="gcn",
            layers=3,
            s_pad=512,
            b_pad=1024,
            d_in=128,
            d_h=64,
            n_class=40,
        )
    ]
)

CONFIG_BY_NAME: Dict[str, ArtifactConfig] = {c.name: c for c in CONFIGS}
