"""Layer-1 Pallas kernel: blocked matmul with fused epilogue.

This is the compute hot-spot of DIGEST's per-subgraph layer step
(Eq. 4/5 of the paper):

    Z = act( P_in @ (H_in @ W)  +  P_out @ (H_stale @ W)  + b )

On the paper's GPU testbed this is a cuSPARSE SpMM + cuBLAS GEMM pair.
For the TPU-style Pallas port we restructure it around the MXU/VMEM
model instead of porting warp-level code (see DESIGN.md
Hardware-Adaptation):

  * the two propagations share the dense transform, so we factor the
    layer as two blocked GEMMs over *concatenated* operands:

        T = [H_in ; H_stale] @ W          # (S+B, d')  "transform"
        Z = act([P_in | P_out] @ T + b)   # (S,   d')  "aggregate"

  * each GEMM is a Pallas kernel with a 3-D grid (M-tiles, N-tiles,
    K-tiles); the K dimension is innermost so the f32 output tile stays
    resident in VMEM across the K loop (accumulate-in-place — the
    canonical MXU pattern, no HBM round-trips for partial sums);

  * the epilogue (bias + activation) is fused into the last K step of
    the aggregate GEMM, so Z is written to HBM exactly once.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (scan over grid with
dynamic slices).  Real-TPU performance is *estimated* from the VMEM
footprint and MXU-utilization model in ``vmem_footprint_bytes`` /
``mxu_utilization`` below and reported in DESIGN.md / EXPERIMENTS.md
per-config.

Autodiff: ``pallas_call`` has no automatic transpose rule, so the public
``pmatmul`` wraps the kernel in a ``jax.custom_vjp`` whose backward pass
is itself two Pallas GEMMs (dX = G @ Y^T, dY = X^T @ G).  Elementwise
epilogues used on the training path are left to XLA fusion (they are not
MXU work); the fused-epilogue entry point ``matmul_bias_act`` is used on
the forward-only (eval) path.
"""

from __future__ import annotations

import functools
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Kernel backend dispatch (§Perf). "pallas" (default) routes the GEMMs
#: through the Pallas kernels — the TPU-targeted path, validated against
#: the oracles; it runs under interpret=True on CPU at ~15x the cost of
#: native XLA dots (measured in EXPERIMENTS.md §Perf).  "xla" emits the
#: same math as plain jnp matmuls for fast CPU execution (what a real
#: deployment would select per backend).  Set via DIGEST_KERNEL_BACKEND
#: or `python -m compile.aot --backend xla`.
BACKEND = os.environ.get("DIGEST_KERNEL_BACKEND", "pallas")


def set_backend(name: str) -> None:
    global BACKEND
    if name not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel backend {name!r}")
    BACKEND = name

# ---------------------------------------------------------------------------
# Block-size selection
# ---------------------------------------------------------------------------

#: Preferred tile edge.  128 matches the MXU systolic-array edge; on the
#: interpret-mode CPU path it simply bounds the unrolled block.
DEFAULT_BLOCK = 128


def pick_block(dim: int, target: int = DEFAULT_BLOCK) -> int:
    """Largest divisor of ``dim`` that is ``<= target``.

    Artifact shapes are chosen to be multiples of friendly sizes, but
    class counts (e.g. 40/41/47) are odd — a single block then covers
    the whole dimension.
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim  # unreachable: 1 always divides


ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "none": lambda z: z,
    "relu": lambda z: jnp.maximum(z, 0.0),
    "leaky_relu": lambda z: jnp.where(z > 0, z, 0.2 * z),
    "elu": lambda z: jnp.where(z > 0, z, jnp.expm1(z)),
}


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------


# Each kernel computes one (bm, bn) output tile; grid = (M/bm, N/bn, K/bk)
# with K innermost.  The output tile acts as the VMEM accumulator: zeroed
# at k == 0, accumulated in-place, epilogue at k == nk - 1.


def _kernel_nobias(x_ref, y_ref, o_ref, *, nk: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    if act != "none":

        @pl.when(k == nk - 1)
        def _epilogue():
            o_ref[...] = ACTIVATIONS[act](o_ref[...])


def _kernel_bias(x_ref, y_ref, b_ref, o_ref, *, nk: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = ACTIVATIONS[act](o_ref[...] + b_ref[...])


def _pallas_matmul(
    x: jax.Array,
    y: jax.Array,
    bias: Optional[jax.Array] = None,
    act: str = "none",
    *,
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
) -> jax.Array:
    """``act(x @ y + bias)`` as a blocked Pallas GEMM.

    x: (M, K) f32, y: (K, N) f32, bias: (N,) f32 or None.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"bad matmul shapes {x.shape} @ {y.shape}")
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    _, n = y.shape
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"blocks ({bm},{bn},{bk}) must divide ({m},{n},{k})")
    nm, nn, nk = m // bm, n // bn, k // bk
    grid = (nm, nn, nk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    y_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))

    if bias is None:
        kernel = functools.partial(_kernel_nobias, nk=nk, act=act)
        in_specs = [x_spec, y_spec]
        operands = (x, y)
    else:
        if bias.shape != (n,):
            raise ValueError(f"bias shape {bias.shape} != ({n},)")
        b2 = bias.reshape(1, n).astype(jnp.float32)
        b_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
        kernel = functools.partial(_kernel_bias, nk=nk, act=act)
        in_specs = [x_spec, y_spec, b_spec]
        operands = (x, y, b2)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(*operands)


# ---------------------------------------------------------------------------
# Autodiff-capable public matmul
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _pmatmul_pallas(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y`` with Pallas forward *and* backward GEMMs."""
    return _pallas_matmul(x, y)


def pmatmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Backend-dispatched GEMM: Pallas kernels or native XLA dot."""
    if BACKEND == "xla":
        return x @ y
    return _pmatmul_pallas(x, y)


def _pmatmul_fwd(x, y):
    return _pallas_matmul(x, y), (x, y)


def _pmatmul_bwd(res, g):
    x, y = res
    # dX = G @ Y^T  (M,K);  dY = X^T @ G  (K,N).  Both are Pallas GEMMs so
    # the backward pass stays on the L1 kernel too.
    return _pallas_matmul(g, y.T), _pallas_matmul(x.T, g)


_pmatmul_pallas.defvjp(_pmatmul_fwd, _pmatmul_bwd)


def matmul_bias_act(x, y, bias=None, act: str = "none"):
    """Forward-only fused GEMM + bias + activation (eval path)."""
    if BACKEND == "xla":
        z = x @ y
        if bias is not None:
            z = z + bias[None, :]
        return ACTIVATIONS[act](z)
    return _pallas_matmul(x, y, bias=bias, act=act)


# ---------------------------------------------------------------------------
# The DIGEST aggregation layer (the paper's Eq. 4 in matrix form, Eq. 5)
# ---------------------------------------------------------------------------


def aggregate_layer(
    p_in: jax.Array,
    p_out: jax.Array,
    h_in: jax.Array,
    h_stale: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    act: str = "relu",
    *,
    fused_epilogue: bool = False,
) -> jax.Array:
    """One DIGEST GCN layer: ``act(P_in·H_in·W + P_out·H̃_out·W + b)``.

    ``fused_epilogue=True`` uses the in-kernel bias+act epilogue (eval /
    forward-only path); ``False`` leaves elementwise work to XLA so the
    layer is differentiable (training path).
    """
    hc = jnp.concatenate([h_in, h_stale], axis=0)  # (S+B, d)
    pc = jnp.concatenate([p_in, p_out], axis=1)  # (S, S+B)
    if fused_epilogue:
        t = matmul_bias_act(hc, w)  # (S+B, d')
        return matmul_bias_act(pc, t, bias=bias, act=act)
    t = pmatmul(hc, w)
    z = pmatmul(pc, t)
    if bias is not None:
        z = z + bias[None, :]
    return ACTIVATIONS[act](z)


# ---------------------------------------------------------------------------
# TPU performance model (structure-level; interpret mode has no TPU clock)
# ---------------------------------------------------------------------------


def vmem_footprint_bytes(m: int, n: int, k: int, bm=None, bn=None, bk=None) -> int:
    """Resident VMEM bytes for one grid step of the GEMM kernel."""
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    # x tile + y tile + output/accumulator tile (+ bias row, negligible)
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm=None, bn=None, bk=None) -> float:
    """Fraction of MXU issue slots doing useful work for these shapes.

    Models the 128x128 systolic array: a (bm, bn, bk) tile issues
    ceil(bm/128)*ceil(bn/128)*ceil(bk/128) MXU passes of 128^3 MACs each;
    utilization is useful MACs over issued MACs.
    """
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)

    def up(v):
        return -(-v // 128) * 128

    useful = m * n * k
    issued = (m // bm) * (n // bn) * (k // bk) * up(bm) * up(bn) * up(bk)
    return useful / issued
