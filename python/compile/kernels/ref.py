"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Everything here is the *specification*: plain jax.numpy with no Pallas,
no blocking, no fusion.  The pytest suite asserts the kernels in
``aggregate.py`` / ``attention.py`` match these to float32 tolerance
across a hypothesis-driven shape/seed sweep.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.2
MASK_NEG = -1e30


def act_ref(z: jax.Array, kind: str) -> jax.Array:
    if kind == "none":
        return z
    if kind == "relu":
        return jnp.maximum(z, 0.0)
    if kind == "leaky_relu":
        return jnp.where(z > 0, z, LEAKY_SLOPE * z)
    if kind == "elu":
        return jnp.where(z > 0, z, jnp.expm1(z))
    raise ValueError(kind)


def matmul_ref(
    x: jax.Array, y: jax.Array, bias: Optional[jax.Array] = None, act: str = "none"
) -> jax.Array:
    z = x @ y
    if bias is not None:
        z = z + bias[None, :]
    return act_ref(z, act)


def aggregate_layer_ref(
    p_in: jax.Array,
    p_out: jax.Array,
    h_in: jax.Array,
    h_stale: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    act: str = "relu",
) -> jax.Array:
    """Eq. 5 of the paper: sigma(P_in·H_in·W + P_out·H̃_out·W + b)."""
    z = p_in @ (h_in @ w) + p_out @ (h_stale @ w)
    if bias is not None:
        z = z + bias[None, :]
    return act_ref(z, act)


def masked_softmax_ref(e: jax.Array, mask: jax.Array) -> jax.Array:
    """Row-wise softmax over entries where ``mask > 0``.

    Fully-masked rows degrade to a uniform distribution (finite, never
    NaN) — such rows only ever correspond to padding and are excluded
    from the loss and from KVS pushes (see DESIGN.md §6).
    """
    e = jnp.where(mask > 0, e, MASK_NEG)
    e = e - jnp.max(e, axis=1, keepdims=True)
    num = jnp.exp(e)
    return num / jnp.sum(num, axis=1, keepdims=True)


def gat_attention_ref(
    g: jax.Array,  # (S+B, d') transformed features [in ; stale]
    s_src: jax.Array,  # (S,)    a_src · g_i for destination nodes
    s_dst: jax.Array,  # (S+B,)  a_dst · g_j for source nodes
    mask: jax.Array,  # (S, S+B) adjacency mask [A_in | A_out], self-loops on diag
) -> jax.Array:
    """GAT aggregation: softmax_j(LeakyReLU(s_src_i + s_dst_j)) @ g."""
    e = s_src[:, None] + s_dst[None, :]
    e = jnp.where(e > 0, e, LEAKY_SLOPE * e)
    alpha = masked_softmax_ref(e, mask)
    return alpha @ g


def l2_normalize_ref(h: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Row L2 normalization (Alg. 1 line 11)."""
    return h / jnp.maximum(jnp.linalg.norm(h, axis=1, keepdims=True), eps)
