"""Layer-1 Pallas kernel: masked GAT attention over [in ∥ stale] neighbors.

The GAT layer under DIGEST's stale split attends over the concatenation
of in-subgraph neighbors (fresh) and out-of-subgraph neighbors (stale,
pulled from the KVS):

    e_ij   = LeakyReLU(a_src · g_i + a_dst · g_j)       j ∈ N(i) ∪ {i}
    alpha  = softmax_j(e_ij)   masked to [A_in | A_out]
    h'_i   = Σ_j alpha_ij g_j

Row-wise softmax needs a full attention row, so the kernel tiles over
*destination rows only*: grid = (S / bm,), each step holding one
(bm, S+B) logits tile plus the full transformed-feature matrix
``g`` (S+B, d') resident in VMEM.  For this library's artifact shapes
(S+B ≤ 3072, d' ≤ 128) that is ≤ 3 MiB — comfortably inside a TPU
core's ~16 MiB VMEM; ``vmem_footprint_bytes`` checks the budget.

Like the aggregate GEMM, this kernel is used on the forward-only path
(eval artifacts, correctness tests); the training path computes the
same math in jnp (XLA-fused elementwise + ``pmatmul`` GEMMs) because
``pallas_call`` has no autodiff transpose rule.  Both are asserted
equal to ``ref.gat_attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .aggregate import pick_block
from .ref import LEAKY_SLOPE, MASK_NEG


def _attention_kernel(s_src_ref, s_dst_ref, mask_ref, g_ref, o_ref):
    """One (bm,)-row tile of masked-softmax attention aggregation."""
    e = s_src_ref[...].reshape(-1, 1) + s_dst_ref[...].reshape(1, -1)  # (bm, S+B)
    e = jnp.where(e > 0, e, LEAKY_SLOPE * e)
    e = jnp.where(mask_ref[...] > 0, e, MASK_NEG)
    e = e - jnp.max(e, axis=1, keepdims=True)
    num = jnp.exp(e)
    alpha = num / jnp.sum(num, axis=1, keepdims=True)
    o_ref[...] = jnp.dot(alpha, g_ref[...], preferred_element_type=jnp.float32)


def gat_attention(
    g: jax.Array,  # (S+B, d')
    s_src: jax.Array,  # (S,)
    s_dst: jax.Array,  # (S+B,)
    mask: jax.Array,  # (S, S+B)
    *,
    bm: int | None = None,
) -> jax.Array:
    """Pallas masked attention aggregation; returns (S, d')."""
    s, sb = mask.shape
    _, dp = g.shape
    if g.shape[0] != sb or s_src.shape != (s,) or s_dst.shape != (sb,):
        raise ValueError(
            f"inconsistent shapes: g={g.shape} s_src={s_src.shape} "
            f"s_dst={s_dst.shape} mask={mask.shape}"
        )
    from .aggregate import BACKEND
    if BACKEND == "xla":
        from .ref import gat_attention_ref
        return gat_attention_ref(g, s_src, s_dst, mask)
    bm = bm or pick_block(s)
    if s % bm:
        raise ValueError(f"row block {bm} must divide {s}")
    grid = (s // bm,)
    return pl.pallas_call(
        _attention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),  # s_src rows
            pl.BlockSpec((sb,), lambda i: (0,)),  # s_dst, full
            pl.BlockSpec((bm, sb), lambda i: (i, 0)),  # mask rows
            pl.BlockSpec((sb, dp), lambda i: (0, 0)),  # g, full
        ],
        out_specs=pl.BlockSpec((bm, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, dp), jnp.float32),
        interpret=True,
    )(s_src, s_dst, mask, g)


def vmem_footprint_bytes(s: int, sb: int, dp: int, bm: int | None = None) -> int:
    """Resident VMEM bytes for one grid step of the attention kernel."""
    bm = bm or pick_block(s)
    # s_src tile + s_dst + mask tile + g + logits scratch + output tile
    return 4 * (bm + sb + bm * sb + sb * dp + bm * sb + bm * dp)
