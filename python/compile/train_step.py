"""Per-subgraph train/eval step builders — the functions that get AOT-lowered.

The train step is one local epoch-step of Alg. 1 on subgraph m:

    fwd (Eq. 4, stale split) -> masked CE loss -> jax.grad
    returns (loss, ncorrect, logits, fresh hidden reps, grads)

Gradients are returned (not applied): the Rust parameter server owns the
optimizer (SGD/momentum/Adam) and the aggregation policy, so one
artifact serves every training mode (DESIGN.md §6.2).

The *flat positional signature* (see ``flat_args``) and the flat output
tuple are the ABI contract recorded in ``artifacts/manifest.json``.
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ArtifactConfig
from .models.gcn import gcn_forward
from .models.gat import gat_forward
from .models.loss import masked_cross_entropy, masked_correct


def _unflatten(cfg: ArtifactConfig, flat: Tuple[jax.Array, ...], kind: str = "train"):
    """Split the flat positional args per the manifest input ordering."""
    i = 0
    x = flat[i]; i += 1
    p_in = flat[i]; i += 1
    p_out = flat[i]; i += 1
    n_stale = cfg.layers - 1
    h_stale = list(flat[i : i + n_stale]); i += n_stale
    keys = cfg.param_keys()
    params = []
    for _ in range(cfg.layers):
        layer = {}
        for k in keys:
            layer[k] = flat[i]; i += 1
        params.append(layer)
    if kind == "train":
        y = flat[i]; i += 1
        mask = flat[i]; i += 1
    else:
        y = mask = None
    assert i == len(flat), f"consumed {i} of {len(flat)} args"
    return x, p_in, p_out, h_stale, params, y, mask


def _forward(cfg: ArtifactConfig, params, x, p_in, p_out, h_stale, *, fused: bool):
    if cfg.model == "gcn":
        return gcn_forward(
            params, x, p_in, p_out, h_stale,
            act=cfg.activation(), normalize=cfg.normalize, fused_epilogue=fused,
        )
    if cfg.model == "gat":
        return gat_forward(
            params, x, p_in, p_out, h_stale,
            act=cfg.activation(), normalize=cfg.normalize, fused_epilogue=fused,
        )
    raise ValueError(f"unknown model {cfg.model!r}")


def _flatten_grads(cfg: ArtifactConfig, grads) -> List[jax.Array]:
    out: List[jax.Array] = []
    for layer in grads:
        for k in cfg.param_keys():
            out.append(layer[k])
    return out


def make_train_step(cfg: ArtifactConfig) -> Callable:
    """Flat-signature train step: ``step(*flat) -> (loss, ncorrect, logits,
    *reps, *grads)``."""

    def step(*flat):
        x, p_in, p_out, h_stale, params, y, mask = _unflatten(cfg, flat)

        def loss_fn(params):
            logits, reps = _forward(
                cfg, params, x, p_in, p_out, h_stale, fused=False
            )
            loss = masked_cross_entropy(logits, y, mask)
            return loss, (logits, reps)

        (loss, (logits, reps)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params
        )
        ncorrect = masked_correct(logits, y, mask)
        return tuple([loss, ncorrect, logits] + reps + _flatten_grads(cfg, grads))

    return step


def make_eval_step(cfg: ArtifactConfig) -> Callable:
    """Forward-only step (fused Pallas epilogue path):
    ``step(*flat) -> (logits, *reps)``.

    Eval takes the train signature *minus* y/mask (unused entry params
    would be dead-code-eliminated by XLA, breaking the buffer count).
    """

    def step(*flat):
        x, p_in, p_out, h_stale, params, _y, _mask = _unflatten(cfg, flat, "eval")
        logits, reps = _forward(cfg, params, x, p_in, p_out, h_stale, fused=True)
        return tuple([logits] + reps)

    return step


def flat_args(cfg: ArtifactConfig, kind: str = "train") -> List[jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs matching the manifest input order (for lowering)."""
    dt = {"f32": jnp.float32, "i32": jnp.int32}
    return [
        jax.ShapeDtypeStruct(shape, dt[dtype])
        for _, shape, dtype in cfg.input_specs(kind)
    ]
