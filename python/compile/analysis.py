"""L2 performance analysis: inspect the lowered HLO of every artifact.

Build-time profiling for the optimization pass (DESIGN.md §7): reports
per-artifact op histograms, dot/fusion counts, parameter + output bytes,
analytic FLOPs, and the L1 kernel's TPU estimates (VMEM footprint / MXU
utilization per GEMM).  Results land in ``artifacts/analysis.json`` and
a human-readable table on stdout.

Usage (from python/):  python -m compile.analysis [--out ../artifacts/analysis.json]
"""

from __future__ import annotations

import argparse
import json
import re
from collections import Counter
from typing import Dict, List

from .configs import CONFIGS, ArtifactConfig
from .aot import lower_config
from .kernels.aggregate import mxu_utilization, pick_block, vmem_footprint_bytes

#: ops that indicate unfused elementwise work (too many = missed fusion)
ELEMENTWISE = {"add", "multiply", "subtract", "divide", "maximum", "exponential"}


def op_histogram(hlo_text: str) -> Counter:
    """Count HLO instructions by opcode (ENTRY + nested computations)."""
    ops: Counter = Counter()
    # `name = <type> opcode(...)` — the type may be a tuple (parens), so
    # find the opcode as the identifier immediately before the first '('
    # that follows the '=' and the type expression
    pat = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*.*?([a-z][a-z\-]*)\(")
    for line in hlo_text.splitlines():
        m = pat.match(line)
        if m:
            ops[m.group(1)] += 1
    return ops


def analytic_flops(cfg: ArtifactConfig, kind: str) -> int:
    """Dense FLOPs of one step (fwd; train ~3x for fwd+bwd)."""
    s, b = cfg.s_pad, cfg.b_pad
    fwd = 0
    for d_in, d_out in zip(cfg.dims(), cfg.dims()[1:]):
        fwd += 2 * ((s + b) * d_in * d_out + s * (s + b) * d_out)
    return 3 * fwd if kind == "train" else fwd


def gemm_estimates(cfg: ArtifactConfig) -> List[Dict]:
    """Per-GEMM TPU structure estimates for the L1 kernel."""
    out = []
    sb = cfg.s_pad + cfg.b_pad
    for name, (m, k, n) in {
        "transform": (sb, cfg.d_in, cfg.d_h),
        "aggregate": (cfg.s_pad, sb, cfg.d_h),
        "classify": (cfg.s_pad, cfg.d_h, cfg.n_class),
    }.items():
        out.append(
            {
                "gemm": name,
                "m": m,
                "k": k,
                "n": n,
                "blocks": [pick_block(m), pick_block(n), pick_block(k)],
                "vmem_bytes": vmem_footprint_bytes(m, n, k),
                "mxu_utilization": round(mxu_utilization(m, n, k), 6),
            }
        )
    return out


def analyze(cfg: ArtifactConfig, kind: str) -> Dict:
    text = lower_config(cfg, kind)
    ops = op_histogram(text)
    total_ops = sum(ops.values())
    input_bytes = sum(
        4 * _prod(s) for _, s, _ in cfg.input_specs(kind)
    )
    output_bytes = sum(4 * _prod(s) for _, s, _ in cfg.output_specs(kind))
    return {
        "name": cfg.name,
        "kind": kind,
        "hlo_bytes": len(text),
        "total_ops": total_ops,
        "dots": ops.get("dot", 0),
        "fusions": ops.get("fusion", 0),
        "while_loops": ops.get("while", 0),
        "elementwise": sum(ops.get(o, 0) for o in ELEMENTWISE),
        "top_ops": dict(ops.most_common(8)),
        "input_bytes": input_bytes,
        "output_bytes": output_bytes,
        "analytic_flops": analytic_flops(cfg, kind),
        "gemms": gemm_estimates(cfg),
    }


def _prod(shape) -> int:
    r = 1
    for d in shape:
        r *= d
    return max(r, 1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/analysis.json")
    ap.add_argument("--only", default="", help="comma-separated config names")
    args = ap.parse_args()
    only = {n for n in args.only.split(",") if n}

    results = []
    print(f"{'artifact':>22} {'kind':5} {'ops':>6} {'dots':>5} {'while':>6} "
          f"{'GFLOP':>7} {'min MXU':>8} {'max VMEM':>9}")
    for cfg in CONFIGS:
        if only and cfg.name not in only:
            continue
        for kind in ("train", "eval"):
            r = analyze(cfg, kind)
            results.append(r)
            min_mxu = min(g["mxu_utilization"] for g in r["gemms"])
            max_vmem = max(g["vmem_bytes"] for g in r["gemms"])
            print(
                f"{r['name']:>22} {kind:5} {r['total_ops']:>6} {r['dots']:>5} "
                f"{r['while_loops']:>6} {r['analytic_flops'] / 1e9:>7.3f} "
                f"{min_mxu:>8.2f} {max_vmem / 2**20:>8.2f}M"
            )
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
