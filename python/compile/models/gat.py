"""L-layer GAT with DIGEST's stale-representation split.

Single-head graph attention (Velickovic et al. 2017), attending over the
concatenation of fresh in-subgraph neighbors and stale out-of-subgraph
neighbors:

    g        = [H_in ; H̃_out] @ W                      (S+B, d')
    e_ij     = LeakyReLU(a_src·g_i + a_dst·g_j)
    alpha_i· = softmax over j with [A_in | A_out] mask  (self-loop on diag)
    h'_i     = sigma(alpha_i· @ g + b)

For GAT the ``p_in`` / ``p_out`` artifact inputs are *binary adjacency
masks* (the Rust halo module emits masks instead of normalized
propagation weights when the model is GAT); the diagonal of the in-mask
is 1 for every row including padding so no softmax row is empty.

Training path: GEMMs via the Pallas ``pmatmul`` (autodiff-capable),
masked softmax in jnp (XLA-fused elementwise).  Forward-only path
(``fused_epilogue=True``) uses the fused Pallas attention kernel.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.aggregate import pmatmul, matmul_bias_act, ACTIVATIONS
from ..kernels.attention import gat_attention
from ..kernels.ref import LEAKY_SLOPE, MASK_NEG, l2_normalize_ref

Params = List[Dict[str, jax.Array]]


def init_gat_params(key: jax.Array, dims: Sequence[int]) -> Params:
    """Per-layer {"w", "b", "a_src", "a_dst"}; Glorot W, small attention vecs."""
    params: Params = []
    for l in range(len(dims) - 1):
        key, kw, ks, kd = jax.random.split(key, 4)
        d_in, d_out = dims[l], dims[l + 1]
        lim = jnp.sqrt(6.0 / (d_in + d_out))
        params.append(
            {
                "w": jax.random.uniform(kw, (d_in, d_out), jnp.float32, -lim, lim),
                "b": jnp.zeros((d_out,), jnp.float32),
                "a_src": 0.1 * jax.random.normal(ks, (d_out,), jnp.float32),
                "a_dst": 0.1 * jax.random.normal(kd, (d_out,), jnp.float32),
            }
        )
    return params


def _attend_jnp(g, s_src, s_dst, mask):
    """Training-path attention: jnp softmax + Pallas GEMM aggregation."""
    e = s_src[:, None] + s_dst[None, :]
    e = jnp.where(e > 0, e, LEAKY_SLOPE * e)
    e = jnp.where(mask > 0, e, MASK_NEG)
    e = e - jax.lax.stop_gradient(jnp.max(e, axis=1, keepdims=True))
    num = jnp.exp(e)
    alpha = num / jnp.sum(num, axis=1, keepdims=True)
    return pmatmul(alpha, g)


def gat_forward(
    params: Params,
    x: jax.Array,  # (S+B, d_in)
    adj_in: jax.Array,  # (S, S) binary mask, diag = 1
    adj_out: jax.Array,  # (S, B) binary mask
    h_stale: Sequence[jax.Array],  # L-1 tensors (B, d_h)
    *,
    act: str = "elu",
    normalize: bool = False,
    fused_epilogue: bool = False,
) -> Tuple[jax.Array, List[jax.Array]]:
    """Returns (logits (S, C), fresh hidden reps [(S, d_h)] * (L-1))."""
    n_layers = len(params)
    if len(h_stale) != n_layers - 1:
        raise ValueError(f"need {n_layers - 1} stale tensors, got {len(h_stale)}")
    s = adj_in.shape[0]
    mask = jnp.concatenate([adj_in, adj_out], axis=1)  # (S, S+B)
    h_in = x[:s]
    h_out = x[s:]
    reps: List[jax.Array] = []
    for l, layer in enumerate(params):
        last = l == n_layers - 1
        hc = jnp.concatenate([h_in, h_out], axis=0)  # (S+B, d)
        if fused_epilogue:
            g = matmul_bias_act(hc, layer["w"])
            s_src = g[:s] @ layer["a_src"]
            s_dst = g @ layer["a_dst"]
            h_new = gat_attention(g, s_src, s_dst, mask)
        else:
            g = pmatmul(hc, layer["w"])
            s_src = g[:s] @ layer["a_src"]
            s_dst = g @ layer["a_dst"]
            h_new = _attend_jnp(g, s_src, s_dst, mask)
        h_new = h_new + layer["b"][None, :]
        if not last:
            h_in = ACTIVATIONS[act](h_new)
            if normalize:
                h_in = l2_normalize_ref(h_in)
            reps.append(h_in)
            h_out = h_stale[l]
        else:
            h_in = h_new
    return h_in, reps
