"""Layer-2 JAX models: GCN / GAT with DIGEST's stale-representation split."""

from .gcn import gcn_forward, init_gcn_params
from .gat import gat_forward, init_gat_params
from .loss import masked_cross_entropy, masked_correct

__all__ = [
    "gcn_forward",
    "init_gcn_params",
    "gat_forward",
    "init_gat_params",
    "masked_cross_entropy",
    "masked_correct",
]
