"""L-layer GCN with DIGEST's stale-representation split (paper Eq. 4/5).

Layer l+1 on subgraph m:

    H_in^(l+1) = sigma( P_in · H_in^(l) · W^(l+1) + P_out · H̃_out^(l) · W^(l+1) + b )

* layer 0's out-of-subgraph input is the *exact* halo feature rows (node
  features are static, never stale);
* hidden layers l >= 1 read the stale halo representations
  ``h_stale[l-1]`` pulled from the KVS by the Rust coordinator;
* the per-layer fresh in-subgraph representations are returned so the
  coordinator can push them back to the KVS (Alg. 1 lines 9-10).

``P_in`` (S, S) and ``P_out`` (S, B) are the GCN-normalized propagation
matrix D̃^{-1/2}(A+I)D̃^{-1/2} split by column ownership; the Rust
``halo`` module builds them (padded, dense).
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.aggregate import aggregate_layer, ACTIVATIONS
from ..kernels.ref import l2_normalize_ref

Params = List[Dict[str, jax.Array]]


def init_gcn_params(
    key: jax.Array, dims: Sequence[int], scale: str = "glorot"
) -> Params:
    """Per-layer {"w": (d_l, d_{l+1}), "b": (d_{l+1},)}; Glorot-uniform W."""
    params: Params = []
    for l in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        d_in, d_out = dims[l], dims[l + 1]
        if scale == "glorot":
            lim = jnp.sqrt(6.0 / (d_in + d_out))
        else:
            lim = 1.0 / jnp.sqrt(d_in)
        w = jax.random.uniform(sub, (d_in, d_out), jnp.float32, -lim, lim)
        params.append({"w": w, "b": jnp.zeros((d_out,), jnp.float32)})
    return params


def gcn_forward(
    params: Params,
    x: jax.Array,  # (S+B, d_in): [in-subgraph rows ; halo rows]
    p_in: jax.Array,  # (S, S)
    p_out: jax.Array,  # (S, B)
    h_stale: Sequence[jax.Array],  # L-1 tensors, each (B, d_h)
    *,
    act: str = "relu",
    normalize: bool = False,
    fused_epilogue: bool = False,
) -> Tuple[jax.Array, List[jax.Array]]:
    """Returns (logits (S, C), fresh hidden reps [(S, d_h)] * (L-1))."""
    n_layers = len(params)
    if len(h_stale) != n_layers - 1:
        raise ValueError(f"need {n_layers - 1} stale tensors, got {len(h_stale)}")
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")

    s = p_in.shape[0]
    h_in = x[:s]
    h_out = x[s:]  # exact halo features for layer 0
    reps: List[jax.Array] = []
    for l, layer in enumerate(params):
        last = l == n_layers - 1
        h_in = aggregate_layer(
            p_in,
            p_out,
            h_in,
            h_out,
            layer["w"],
            bias=layer["b"],
            act="none" if last else act,
            fused_epilogue=fused_epilogue,
        )
        if not last:
            if normalize:
                h_in = l2_normalize_ref(h_in)
            reps.append(h_in)
            h_out = h_stale[l]  # stale input for the next layer
    return h_in, reps


def gcn_forward_dims(d_in: int, d_h: int, n_class: int, layers: int) -> List[int]:
    """[d_in, d_h, ..., d_h, n_class] — the dims list for init/params."""
    return [d_in] + [d_h] * (layers - 1) + [n_class]
