"""Masked losses/metrics for padded per-subgraph batches.

Every subgraph is padded to the artifact's static shape (S_pad rows);
``mask`` is 1.0 for real train nodes and 0.0 for padding / non-train
nodes, so padded rows contribute nothing to the loss or the metrics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_cross_entropy(logits: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy over rows with ``mask > 0``.

    logits: (S, C) f32; y: (S,) int32; mask: (S,) f32.
    The denominator is clamped to 1 so an all-masked batch yields 0, not
    NaN (can happen for a padding-only subgraph in degenerate splits).
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def masked_correct(logits: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Number of correctly-classified rows with ``mask > 0`` (f32 scalar)."""
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32) * mask)
