"""AOT pipeline tests: lowering determinism, HLO-text validity, and an
execute-the-lowered-module check through the CPU PJRT client (the same
compile path the Rust runtime uses, minus the Rust FFI)."""

import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_config, to_hlo_text
from compile.configs import CONFIG_BY_NAME, CONFIGS, ArtifactConfig
from compile.train_step import flat_args, make_train_step

TINY = ArtifactConfig(
    name="tiny", model="gcn", layers=2, s_pad=8, b_pad=8, d_in=4, d_h=4, n_class=3
)


def test_hlo_text_has_entry_and_params():
    text = lower_config(TINY, "train")
    assert "ENTRY" in text
    # all 10 inputs present as parameters
    n_params = len(set(re.findall(r"parameter\((\d+)\)", text)))
    assert n_params == len(TINY.input_specs())


def test_lowering_is_deterministic():
    t1 = lower_config(TINY, "eval")
    t2 = lower_config(TINY, "eval")
    assert t1 == t2


def test_configs_unique_names_and_sane_shapes():
    names = [c.name for c in CONFIGS]
    assert len(names) == len(set(names))
    for c in CONFIGS:
        assert c.s_pad > 0 and c.b_pad > 0 and c.layers >= 2
        assert c.model in ("gcn", "gat")
        # names referenced by the Rust dataset registry must exist
    for required in ("karate_gcn", "arxiv_s_gcn", "products_s_gat"):
        assert required in CONFIG_BY_NAME


def test_manifest_json_serializable():
    blob = json.dumps(
        [c.to_manifest("train", f"{c.name}_train.hlo.txt") for c in CONFIGS]
    )
    parsed = json.loads(blob)
    assert len(parsed) == len(CONFIGS)


def test_lowered_module_executes_and_matches_direct_call():
    """Compile the lowered StableHLO via the PJRT CPU client and compare
    against calling the jitted function directly — validates that what we
    write to disk computes the right numbers."""
    cfg = TINY
    step = make_train_step(cfg)
    rng = np.random.default_rng(0)
    flat = []
    for name, shape, dtype in cfg.input_specs():
        if dtype == "i32":
            flat.append(jnp.asarray(rng.integers(0, cfg.n_class, shape), jnp.int32))
        elif name == "mask":
            flat.append(jnp.ones(shape, jnp.float32))
        else:
            flat.append(jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.3))

    direct = step(*flat)

    lowered = jax.jit(step).lower(*flat)
    compiled = lowered.compile()
    via_pjrt = compiled(*flat)

    for a, b in zip(direct, via_pjrt):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_hlo_text_round_trips_through_xla_parser():
    """The text we emit must be parseable back (what the Rust side does)."""
    from jax._src.lib import xla_client as xc

    text = lower_config(TINY, "eval")
    # xla_client exposes the HLO text parser used by xla_extension
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
