"""Config-system invariants across ALL artifact configs — the contract
both sides of the ABI rely on."""

import jax.numpy as jnp
import pytest

from compile.configs import CONFIGS, CONFIG_BY_NAME, ArtifactConfig
from compile.kernels.aggregate import pick_block


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
class TestEveryConfig:
    def test_dims_chain(self, cfg):
        dims = cfg.dims()
        assert dims[0] == cfg.d_in
        assert dims[-1] == cfg.n_class
        assert len(dims) == cfg.layers + 1
        for d in dims[1:-1]:
            assert d == cfg.d_h

    def test_train_inputs_order(self, cfg):
        names = [n for n, _, _ in cfg.input_specs("train")]
        assert names[:3] == ["x", "p_in", "p_out"]
        for l in range(cfg.layers - 1):
            assert names[3 + l] == f"h_stale_{l}"
        assert names[-2:] == ["y", "mask"]
        # eval omits y/mask, everything else identical
        eval_names = [n for n, _, _ in cfg.input_specs("eval")]
        assert eval_names == names[:-2]

    def test_param_specs_per_model(self, cfg):
        names = [n for n, _, _ in cfg.input_specs("train")]
        ppl = 2 if cfg.model == "gcn" else 4
        n_params = sum(1 for n in names if n.startswith("l"))
        assert n_params == ppl * cfg.layers

    def test_shapes_consistent(self, cfg):
        specs = {n: (s, t) for n, s, t in cfg.input_specs("train")}
        assert specs["x"][0] == (cfg.s_pad + cfg.b_pad, cfg.d_in)
        assert specs["p_in"][0] == (cfg.s_pad, cfg.s_pad)
        assert specs["p_out"][0] == (cfg.s_pad, cfg.b_pad)
        assert specs["y"][1] == "i32"
        assert specs["l0_w"][0] == (cfg.d_in, cfg.d_h if cfg.layers > 1 else cfg.n_class)

    def test_train_outputs_order(self, cfg):
        names = [n for n, _, _ in cfg.output_specs("train")]
        assert names[:3] == ["loss", "ncorrect", "logits"]
        n_reps = cfg.layers - 1
        for l in range(n_reps):
            assert names[3 + l] == f"rep_{l}"
        grads = names[3 + n_reps:]
        assert all(g.startswith("grad_") for g in grads)
        # grads mirror the param input ordering exactly
        params = [n for n, _, _ in cfg.input_specs("train") if n.startswith("l")]
        assert grads == [f"grad_{p}" for p in params]

    def test_blockable_shapes(self, cfg):
        # every GEMM dim must admit a block (pick_block always succeeds,
        # but catastrophically small blocks mean a bad config)
        for dim in [cfg.s_pad, cfg.b_pad, cfg.s_pad + cfg.b_pad, cfg.d_in, cfg.d_h]:
            assert pick_block(dim) >= min(dim, 32), f"{cfg.name}: dim {dim}"

    def test_activation_default(self, cfg):
        assert cfg.activation() == ("relu" if cfg.model == "gcn" else "elu")


def test_registry_names_cover_rust_datasets():
    # lockstep with rust/src/graph/registry.rs
    for prefix in ["karate", "arxiv_s", "flickr_s", "reddit_s", "products_s"]:
        for model in ["gcn", "gat"]:
            assert f"{prefix}_{model}" in CONFIG_BY_NAME


def test_input_bytes_fit_memory_budget():
    # each step's input tensor set must stay well under 1 GiB (packing
    # creates one host copy)
    for cfg in CONFIGS:
        total = sum(
            4 * int(jnp.prod(jnp.array(s))) if s else 4
            for _, s, _ in cfg.input_specs("train")
        )
        assert total < 2**30, f"{cfg.name}: {total} bytes"
