"""L1 attention kernel tests: Pallas masked attention vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import gat_attention, vmem_footprint_bytes


def _setup(rng, s, b, dp, density=0.3):
    g = jnp.asarray(rng.normal(size=(s + b, dp)).astype(np.float32))
    s_src = jnp.asarray(rng.normal(size=(s,)).astype(np.float32))
    s_dst = jnp.asarray(rng.normal(size=(s + b,)).astype(np.float32))
    mask = (rng.random((s, s + b)) < density).astype(np.float32)
    mask[:, :s] = np.maximum(mask[:, :s], np.eye(s, dtype=np.float32))
    return g, s_src, s_dst, jnp.asarray(mask)


@given(
    s=st.sampled_from([4, 16, 32, 64]),
    b=st.sampled_from([0, 8, 32, 96]),
    dp=st.sampled_from([1, 8, 24, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_attention_matches_ref(s, b, dp, seed):
    rng = np.random.default_rng(seed)
    g, s_src, s_dst, mask = _setup(rng, s, b, dp)
    got = gat_attention(g, s_src, s_dst, mask)
    want = ref.gat_attention_ref(g, s_src, s_dst, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_rows_are_convex_combinations():
    """alpha rows sum to 1 => output rows lie in the span of selected g."""
    rng = np.random.default_rng(1)
    s, b, dp = 16, 16, 4
    g, s_src, s_dst, mask = _setup(rng, s, b, dp, density=0.5)
    # constant feature -> every output row equals that constant
    g_const = jnp.ones_like(g) * 3.5
    out = gat_attention(g_const, s_src, s_dst, mask)
    np.testing.assert_allclose(out, 3.5 * jnp.ones((s, dp)), rtol=1e-5, atol=1e-5)


def test_attention_fully_masked_row_is_finite():
    """Padding rows (no neighbors at all) must not produce NaN/Inf —
    they are masked downstream but NaN would poison the matmuls."""
    rng = np.random.default_rng(2)
    s, b, dp = 8, 8, 4
    g, s_src, s_dst, mask = _setup(rng, s, b, dp)
    mask = mask.at[3, :].set(0.0)  # simulate a padding row
    out = gat_attention(g, s_src, s_dst, mask)
    assert np.all(np.isfinite(np.asarray(out)))


def test_attention_respects_mask():
    """Entries outside the mask must have zero influence."""
    rng = np.random.default_rng(4)
    s, b, dp = 8, 8, 4
    g, s_src, s_dst, mask = _setup(rng, s, b, dp, density=0.4)
    out1 = gat_attention(g, s_src, s_dst, mask)
    # perturb g rows that node 0 does NOT attend to
    blocked = np.where(np.asarray(mask[0]) == 0)[0]
    g2 = np.asarray(g).copy()
    g2[blocked] += 100.0
    out2 = gat_attention(jnp.asarray(g2), s_src, s_dst, mask)
    np.testing.assert_allclose(out1[0], out2[0], rtol=1e-4, atol=1e-4)


def test_attention_shape_validation():
    with pytest.raises(ValueError):
        gat_attention(
            jnp.zeros((10, 4)), jnp.zeros((4,)), jnp.zeros((10,)), jnp.zeros((5, 10))
        )


def test_attention_vmem_budget_for_all_configs():
    from compile.configs import CONFIGS

    budget = 16 * 2**20
    for cfg in CONFIGS:
        if cfg.model != "gat":
            continue
        fp = vmem_footprint_bytes(cfg.s_pad, cfg.s_pad + cfg.b_pad, cfg.d_h)
        assert fp < budget, (cfg.name, fp)
