"""L2 model tests: GCN/GAT forward with the stale split.

The key semantic properties of DIGEST's forward (paper §3.1):

  * if the stale representations equal the *true* ones, the subgraph
    forward equals the exact full-graph forward restricted to the
    subgraph (zero staleness error);
  * if P_out = 0 and stale = 0 the model degrades to the partition-based
    (edge-dropping) computation;
  * the fused (eval) and unfused (train) paths agree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models.gcn import gcn_forward, gcn_forward_dims, init_gcn_params
from compile.models.gat import gat_forward, init_gat_params
from compile.kernels.ref import act_ref, masked_softmax_ref, LEAKY_SLOPE


def _norm_prop(adj):
    """GCN normalization D̃^-1/2 (A+I) D̃^-1/2 (dense, numpy)."""
    a = adj + np.eye(adj.shape[0], dtype=np.float32)
    d = a.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(d, 1e-12))
    return (a * dinv[:, None]) * dinv[None, :]


def _random_graph(rng, n, density=0.2):
    adj = (rng.random((n, n)) < density).astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0.0)
    return adj


def _full_graph_gcn(params, p, x, act="relu"):
    """Exact full-graph GCN (the no-staleness oracle)."""
    h = x
    for l, layer in enumerate(params):
        z = p @ h @ np.asarray(layer["w"]) + np.asarray(layer["b"])[None, :]
        h = np.asarray(act_ref(jnp.asarray(z), act)) if l < len(params) - 1 else z
    return h


def _split(p, own):
    """Split full propagation matrix rows `own` into (p_in, p_out, perm).

    Column order: owned nodes first, then the rest (the halo)."""
    others = [i for i in range(p.shape[0]) if i not in own]
    perm = own + others
    rows = p[own][:, perm]
    return rows[:, : len(own)], rows[:, len(own):], perm


@pytest.mark.parametrize("layers", [2, 3])
def test_gcn_zero_staleness_matches_full_graph(layers):
    rng = np.random.default_rng(0)
    n, d, dh, c = 24, 8, 6, 4
    adj = _random_graph(rng, n)
    p = _norm_prop(adj)
    x = rng.normal(size=(n, d)).astype(np.float32)
    params = init_gcn_params(jax.random.key(0), gcn_forward_dims(d, dh, c, layers))

    full = _full_graph_gcn(params, p, x)
    full_hidden = []  # exact per-layer hidden reps
    h = x
    for l, layer in enumerate(params[:-1]):
        z = p @ h @ np.asarray(layer["w"]) + np.asarray(layer["b"])[None, :]
        h = np.maximum(z, 0.0)
        full_hidden.append(h)

    own = [1, 3, 5, 7, 9, 11]
    p_in, p_out, perm = _split(p, own)
    halo = perm[len(own):]
    x_cat = jnp.asarray(np.concatenate([x[own], x[halo]], axis=0))
    # stale = exact hidden reps of halo nodes
    stale = [jnp.asarray(fh[halo]) for fh in full_hidden]

    logits, reps = gcn_forward(
        params, x_cat, jnp.asarray(p_in), jnp.asarray(p_out), stale
    )
    np.testing.assert_allclose(logits, full[own], rtol=1e-3, atol=1e-4)
    for got, fh in zip(reps, full_hidden):
        np.testing.assert_allclose(got, fh[own], rtol=1e-3, atol=1e-4)


def test_gcn_zero_stale_is_partition_baseline():
    rng = np.random.default_rng(1)
    s, b, d, dh, c = 12, 8, 6, 5, 3
    adj = _random_graph(rng, s)
    p_in = jnp.asarray(_norm_prop(adj))
    p_out = jnp.zeros((s, b))
    x = jnp.asarray(rng.normal(size=(s + b, d)).astype(np.float32))
    params = init_gcn_params(jax.random.key(1), [d, dh, c])
    stale = [jnp.zeros((b, dh))]
    logits, _ = gcn_forward(params, x, p_in, p_out, stale)
    # partition-based oracle: drop all cross-subgraph terms
    h = np.maximum(
        np.asarray(p_in) @ np.asarray(x[:s]) @ np.asarray(params[0]["w"])
        + np.asarray(params[0]["b"]),
        0,
    )
    want = np.asarray(p_in) @ h @ np.asarray(params[1]["w"]) + np.asarray(
        params[1]["b"]
    )
    np.testing.assert_allclose(logits, want, rtol=1e-3, atol=1e-4)


def test_gcn_fused_matches_unfused():
    rng = np.random.default_rng(2)
    s, b, d, dh, c = 16, 16, 8, 8, 4
    p_in = jnp.asarray(rng.random((s, s)).astype(np.float32))
    p_out = jnp.asarray(rng.random((s, b)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(s + b, d)).astype(np.float32))
    stale = [jnp.asarray(rng.normal(size=(b, dh)).astype(np.float32))]
    params = init_gcn_params(jax.random.key(2), [d, dh, c])
    l1, r1 = gcn_forward(params, x, p_in, p_out, stale, fused_epilogue=False)
    l2, r2 = gcn_forward(params, x, p_in, p_out, stale, fused_epilogue=True)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(r1[0], r2[0], rtol=1e-4, atol=1e-4)


def test_gcn_normalize_rows_unit_norm():
    rng = np.random.default_rng(3)
    s, b, d, dh, c = 8, 8, 4, 4, 2
    p_in = jnp.asarray(np.eye(s, dtype=np.float32))
    p_out = jnp.zeros((s, b))
    x = jnp.asarray(rng.normal(size=(s + b, d)).astype(np.float32))
    stale = [jnp.zeros((b, dh))]
    params = init_gcn_params(jax.random.key(3), [d, dh, c])
    _, reps = gcn_forward(params, x, p_in, p_out, stale, normalize=True)
    norms = np.linalg.norm(np.asarray(reps[0]), axis=1)
    nz = norms > 1e-6  # rows that weren't all-zero after relu
    np.testing.assert_allclose(norms[nz], 1.0, rtol=1e-5)


def test_gcn_stale_count_validation():
    params = init_gcn_params(jax.random.key(0), [4, 4, 2])
    with pytest.raises(ValueError):
        gcn_forward(params, jnp.zeros((8, 4)), jnp.zeros((4, 4)), jnp.zeros((4, 4)), [])


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------


def _full_graph_gat(params, adj, x, act="elu"):
    """Exact full-graph single-head GAT oracle (numpy/jnp, no staleness)."""
    n = adj.shape[0]
    mask = jnp.asarray(np.maximum(adj, np.eye(n, dtype=np.float32)))
    h = jnp.asarray(x)
    hidden = []
    for l, layer in enumerate(params):
        g = h @ layer["w"]
        e = (g @ layer["a_src"])[:, None] + (g @ layer["a_dst"])[None, :]
        e = jnp.where(e > 0, e, LEAKY_SLOPE * e)
        alpha = masked_softmax_ref(e, mask)
        z = alpha @ g + layer["b"][None, :]
        if l < len(params) - 1:
            h = act_ref(z, act)
            hidden.append(h)
        else:
            h = z
    return np.asarray(h), [np.asarray(v) for v in hidden]


@pytest.mark.parametrize("fused", [False, True])
def test_gat_zero_staleness_matches_full_graph(fused):
    rng = np.random.default_rng(4)
    n, d, dh, c = 20, 6, 5, 3
    adj = _random_graph(rng, n, density=0.3)
    x = rng.normal(size=(n, d)).astype(np.float32)
    params = init_gat_params(jax.random.key(4), [d, dh, c])
    full, hidden = _full_graph_gat(params, adj, x)

    own = [0, 2, 4, 6, 8]
    others = [i for i in range(n) if i not in own]
    mask_full = np.maximum(adj, np.eye(n, dtype=np.float32))
    adj_in = mask_full[np.ix_(own, own)]
    adj_out = mask_full[np.ix_(own, others)]
    x_cat = jnp.asarray(np.concatenate([x[own], x[others]], axis=0))
    stale = [jnp.asarray(hidden[0][others])]

    logits, reps = gat_forward(
        params, x_cat, jnp.asarray(adj_in), jnp.asarray(adj_out), stale,
        fused_epilogue=fused,
    )
    np.testing.assert_allclose(logits, full[own], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(reps[0], hidden[0][own], rtol=1e-3, atol=1e-4)


def test_gat_fused_matches_unfused():
    rng = np.random.default_rng(5)
    s, b, d, dh, c = 12, 12, 6, 6, 3
    adj_in = (rng.random((s, s)) < 0.4).astype(np.float32)
    np.fill_diagonal(adj_in, 1.0)
    adj_out = (rng.random((s, b)) < 0.3).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(s + b, d)).astype(np.float32))
    stale = [jnp.asarray(rng.normal(size=(b, dh)).astype(np.float32))]
    params = init_gat_params(jax.random.key(5), [d, dh, c])
    l1, _ = gat_forward(params, x, jnp.asarray(adj_in), jnp.asarray(adj_out), stale)
    l2, _ = gat_forward(
        params, x, jnp.asarray(adj_in), jnp.asarray(adj_out), stale,
        fused_epilogue=True,
    )
    np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-4)


def test_gat_grads_flow_through_attention_params():
    rng = np.random.default_rng(6)
    s, b, d, dh, c = 8, 8, 4, 4, 2
    adj_in = np.eye(s, dtype=np.float32)
    adj_out = (rng.random((s, b)) < 0.5).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(s + b, d)).astype(np.float32))
    stale = [jnp.asarray(rng.normal(size=(b, dh)).astype(np.float32))]
    params = init_gat_params(jax.random.key(6), [d, dh, c])

    def loss(params):
        logits, _ = gat_forward(
            params, x, jnp.asarray(adj_in), jnp.asarray(adj_out), stale
        )
        return jnp.sum(logits**2)

    grads = jax.grad(loss)(params)
    for l, layer in enumerate(grads):
        for key in ("w", "a_src", "a_dst"):
            assert float(jnp.sum(jnp.abs(layer[key]))) > 0, (l, key)
