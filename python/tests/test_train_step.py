"""Train/eval-step tests: the flat ABI the Rust runtime consumes.

Checks the flat signature against the manifest specs, the loss/metric
semantics, gradient correctness vs a pure-jnp model, and padding
invariance (padded rows must not change loss or gradients — the
property the Rust halo/padding module relies on).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ArtifactConfig, CONFIGS
from compile.models.loss import masked_cross_entropy, masked_correct
from compile.train_step import flat_args, make_eval_step, make_train_step

TINY_GCN = ArtifactConfig(
    name="t_gcn", model="gcn", layers=2, s_pad=16, b_pad=16, d_in=8, d_h=8, n_class=4
)
TINY_GAT = ArtifactConfig(
    name="t_gat", model="gat", layers=2, s_pad=16, b_pad=16, d_in=8, d_h=8, n_class=4
)
TINY_L3 = ArtifactConfig(
    name="t_l3", model="gcn", layers=3, s_pad=16, b_pad=16, d_in=8, d_h=8, n_class=4
)


def _random_inputs(cfg, rng, train_frac=0.5):
    flat = []
    for name, shape, dtype in cfg.input_specs():
        if dtype == "i32":
            flat.append(jnp.asarray(rng.integers(0, cfg.n_class, shape), jnp.int32))
        elif name == "mask":
            flat.append(
                jnp.asarray((rng.random(shape) < train_frac).astype(np.float32))
            )
        elif name in ("p_in", "p_out"):
            m = (rng.random(shape) < 0.3).astype(np.float32) * 0.2
            if name == "p_in" and cfg.model == "gat":
                m = np.maximum(m, np.eye(shape[0], dtype=np.float32))
            elif name == "p_in":
                m = m + np.eye(shape[0], dtype=np.float32) * 0.5
            flat.append(jnp.asarray(m))
        else:
            flat.append(jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.3))
    return flat


@pytest.mark.parametrize("cfg", [TINY_GCN, TINY_GAT, TINY_L3], ids=lambda c: c.name)
def test_train_step_output_shapes_match_manifest(cfg):
    rng = np.random.default_rng(0)
    flat = _random_inputs(cfg, rng)
    out = make_train_step(cfg)(*flat)
    specs = cfg.output_specs("train")
    assert len(out) == len(specs)
    for val, (name, shape, dtype) in zip(out, specs):
        assert tuple(val.shape) == tuple(shape), name
        assert np.all(np.isfinite(np.asarray(val))), name


@pytest.mark.parametrize("cfg", [TINY_GCN, TINY_GAT], ids=lambda c: c.name)
def test_eval_step_output_shapes_match_manifest(cfg):
    rng = np.random.default_rng(1)
    flat = _random_inputs(cfg, rng)[:-2]  # eval signature drops y/mask
    out = make_eval_step(cfg)(*flat)
    specs = cfg.output_specs("eval")
    assert len(out) == len(specs)
    for val, (name, shape, _) in zip(out, specs):
        assert tuple(val.shape) == tuple(shape), name


def test_flat_args_match_input_specs():
    for cfg in CONFIGS:
        structs = flat_args(cfg)
        specs = cfg.input_specs()
        assert len(structs) == len(specs)
        for s, (_, shape, dtype) in zip(structs, specs):
            assert tuple(s.shape) == tuple(shape)
            assert s.dtype == (jnp.int32 if dtype == "i32" else jnp.float32)


def test_loss_and_ncorrect_semantics():
    logits = jnp.asarray(
        [[5.0, 0.0, 0.0], [0.0, 5.0, 0.0], [0.0, 0.0, 5.0], [5.0, 0.0, 0.0]]
    )
    y = jnp.asarray([0, 1, 0, 0], jnp.int32)  # row 2 wrong
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0])  # row 3 masked out
    assert float(masked_correct(logits, y, mask)) == 2.0
    loss_all = masked_cross_entropy(logits, y, mask)
    # masked-out row 3 is a perfect prediction; adding it would lower the
    # mean, so the masked loss must be higher
    loss_with = masked_cross_entropy(logits, y, jnp.ones(4))
    assert float(loss_all) > float(loss_with)
    # all-masked batch -> exactly 0, no NaN
    assert float(masked_cross_entropy(logits, y, jnp.zeros(4))) == 0.0


def test_train_step_grads_match_pure_jnp():
    """End-to-end gradient check of the lowered function vs plain jnp."""
    cfg = TINY_GCN
    rng = np.random.default_rng(2)
    flat = _random_inputs(cfg, rng)
    out = make_train_step(cfg)(*flat)
    specs = [n for n, _, _ in cfg.input_specs()]
    x, p_in, p_out = flat[0], flat[1], flat[2]
    h_stale = flat[3]
    w0, b0, w1, b1 = flat[4], flat[5], flat[6], flat[7]
    y, mask = flat[8], flat[9]
    s = cfg.s_pad

    def jnp_loss(w0, b0, w1, b1):
        h0_in, h0_out = x[:s], x[s:]
        z1 = p_in @ h0_in @ w0 + p_out @ h0_out @ w0 + b0[None, :]
        h1 = jnp.maximum(z1, 0.0)
        logits = p_in @ h1 @ w1 + p_out @ h_stale @ w1 + b1[None, :]
        return masked_cross_entropy(logits, y, mask)

    ref_grads = jax.grad(jnp_loss, argnums=(0, 1, 2, 3))(w0, b0, w1, b1)
    got = dict(zip([n for n, _, _ in cfg.output_specs("train")], out))
    np.testing.assert_allclose(
        float(got["loss"]), float(jnp_loss(w0, b0, w1, b1)), rtol=1e-4
    )
    for name, rg in zip(
        ["grad_l0_w", "grad_l0_b", "grad_l1_w", "grad_l1_b"], ref_grads
    ):
        np.testing.assert_allclose(got[name], rg, rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("cfg", [TINY_GCN, TINY_GAT], ids=lambda c: c.name)
def test_padding_invariance(cfg):
    """Zero-padded rows (x=0, P rows/cols=0, mask=0) must not change the
    loss, the gradients, or the real rows of logits/reps."""
    rng = np.random.default_rng(3)
    flat = _random_inputs(cfg, rng)
    s, b = cfg.s_pad, cfg.b_pad
    s_real, b_real = 10, 9  # rows beyond these are padding

    def padded(flat):
        out = []
        for val, (name, shape, dtype) in zip(flat, cfg.input_specs()):
            v = np.asarray(val).copy()
            if name == "x":
                v[s_real:s] = 0
                v[s + b_real:] = 0
            elif name == "p_in":
                v[s_real:, :] = 0
                v[:, s_real:] = 0
                if cfg.model == "gat":
                    ii = np.arange(s_real, s)
                    v[ii, ii] = 1.0  # keep self-loop on padded rows
            elif name == "p_out":
                v[s_real:, :] = 0
                v[:, b_real:] = 0
            elif name.startswith("h_stale"):
                v[b_real:] = 0
            elif name == "mask":
                v[s_real:] = 0
            out.append(jnp.asarray(v))
        return out

    base = padded(flat)
    out1 = make_train_step(cfg)(*base)
    # now perturb ONLY padded regions of x / stale; results must not move
    pert = []
    for val, (name, shape, dtype) in zip(base, cfg.input_specs()):
        v = np.asarray(val).copy()
        if name == "x":
            v[s + b_real:] += 7.7  # padded halo rows
        elif name.startswith("h_stale"):
            v[b_real:] -= 3.3
        pert.append(jnp.asarray(v))
    out2 = make_train_step(cfg)(*pert)
    names = [n for n, _, _ in cfg.output_specs("train")]
    for name, a, b_ in zip(names, out1, out2):
        a, b_ = np.asarray(a), np.asarray(b_)
        if name == "logits" or name.startswith("rep_"):
            np.testing.assert_allclose(
                a[:s_real], b_[:s_real], rtol=1e-4, atol=1e-5, err_msg=name
            )
        else:
            np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5, err_msg=name)


def test_manifest_serialization_round_trip():
    cfg = TINY_GCN
    m = cfg.to_manifest("train", "x.hlo.txt")
    assert m["kind"] == "train"
    assert m["act"] == "relu"
    assert [i["name"] for i in m["inputs"]][:4] == ["x", "p_in", "p_out", "h_stale_0"]
    assert m["inputs"][-1]["name"] == "mask"
    assert m["outputs"][0]["name"] == "loss"
    assert m["outputs"][-1]["name"] == "grad_l1_b"
