"""Trainability + staleness semantics at the JAX level.

A miniature DIGEST run entirely in Python: two subgraphs of a ring-of-
cliques graph, train via the flat train step with Adam, exchanging stale
representations through a dict standing in for the KVS.  Verifies the
system-level claims before Rust ever enters the picture:

  * the local steps drive the loss down (end-to-end trainability);
  * periodic stale exchange beats no exchange (LLCG-style) on a task
    where the label signal lives in the *neighbors*;
  * staleness age degrades gracefully (N=1 >= N=big in final quality).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.configs import ArtifactConfig
from compile.train_step import make_train_step

S, B, D, DH, C = 24, 24, 8, 8, 3

CFG = ArtifactConfig(
    name="conv", model="gcn", layers=2, s_pad=S, b_pad=B, d_in=D, d_h=DH, n_class=C
)


def _ring_of_cliques(rng, n=48, k=3):
    """Weak per-node features + same-class edges that deliberately cross
    the partition boundary (i <-> i+n/2 share a class since (n/2) % k == 0):
    denoising requires aggregating *out-of-subgraph* neighbors, so the
    task separates exchange from no-exchange."""
    labels = np.array([i % k for i in range(n)])
    adj = np.zeros((n, n), dtype=np.float32)
    half = n // 2
    assert half % k == 0
    for i in range(half):
        adj[i, i + half] = adj[i + half, i] = 1.0  # cross-partition, same class
    for i in range(n):
        # ring within class for connectivity (mostly intra-partition)
        same = np.where(labels == labels[i])[0]
        pos = np.where(same == i)[0][0]
        j = same[(pos + 1) % len(same)]
        adj[i, j] = adj[j, i] = 1.0
    feats = rng.normal(size=(n, D)).astype(np.float32)
    # weak class signal: features alone classify poorly, neighbor
    # aggregation (including cross edges) denoises it
    cent = rng.normal(size=(k, D)).astype(np.float32) * 0.45
    feats += cent[labels]
    return adj, feats, labels


def _norm_prop(adj):
    a = adj + np.eye(adj.shape[0], dtype=np.float32)
    dinv = 1.0 / np.sqrt(a.sum(1))
    return a * dinv[:, None] * dinv[None, :]


def _setup(rng):
    adj, feats, labels = _ring_of_cliques(rng)
    p = _norm_prop(adj)
    own0 = list(range(0, 24))
    own1 = list(range(24, 48))
    plans = []
    for own, other in [(own0, own1), (own1, own0)]:
        p_in = np.zeros((S, S), np.float32)
        p_out = np.zeros((S, B), np.float32)
        p_in[: len(own), : len(own)] = p[np.ix_(own, own)]
        p_out[: len(own), : len(other)] = p[np.ix_(own, other)]
        x = np.zeros((S + B, D), np.float32)
        x[: len(own)] = feats[own]
        x[S : S + len(other)] = feats[other]
        y = np.zeros(S, np.int32)
        y[: len(own)] = labels[own]
        # hold out every 4th node for validation
        mask = np.zeros(S, np.float32)
        val_mask = np.zeros(S, np.float32)
        for i in range(len(own)):
            if i % 4 == 3:
                val_mask[i] = 1.0
            else:
                mask[i] = 1.0
        plans.append(
            dict(
                own=own, other=other, p_in=p_in, p_out=p_out, x=x, y=y,
                mask=mask, val_mask=val_mask,
            )
        )
    return plans


def _init_params(rng):
    lim0 = np.sqrt(6.0 / (D + DH))
    lim1 = np.sqrt(6.0 / (DH + C))
    return [
        rng.uniform(-lim0, lim0, (D, DH)).astype(np.float32),
        np.zeros(DH, np.float32),
        rng.uniform(-lim1, lim1, (DH, C)).astype(np.float32),
        np.zeros(C, np.float32),
    ]


def _adam_state(params):
    return [np.zeros_like(p) for p in params], [np.zeros_like(p) for p in params]


def _adam(params, grads, m, v, t, lr=0.05):
    out = []
    for i, (p, g) in enumerate(zip(params, grads)):
        m[i] = 0.9 * m[i] + 0.1 * g
        v[i] = 0.999 * v[i] + 0.001 * g * g
        mh = m[i] / (1 - 0.9**t)
        vh = v[i] / (1 - 0.999**t)
        out.append(p - lr * mh / (np.sqrt(vh) + 1e-8))
    return out


def _train(sync_interval, epochs=30, exchange=True, seed=0):
    """Returns (losses per epoch, final held-out accuracy)."""
    rng = np.random.default_rng(seed)
    plans = _setup(rng)
    params = _init_params(rng)
    step = make_train_step(CFG)
    kvs = {}  # node id -> rep row
    stale = [np.zeros((B, DH), np.float32) for _ in plans]
    m, v = _adam_state(params)
    losses = []
    val_correct, val_total = 0.0, 0.0
    for r in range(epochs):
        grads_acc = None
        loss_epoch = 0.0
        val_correct, val_total = 0.0, 0.0
        for w, plan in enumerate(plans):
            if exchange and r % sync_interval == 0:
                fresh = np.zeros((B, DH), np.float32)
                for j, node in enumerate(plan["other"]):
                    if node in kvs:
                        fresh[j] = kvs[node]
                stale[w] = fresh
            out = step(
                jnp.asarray(plan["x"]),
                jnp.asarray(plan["p_in"]),
                jnp.asarray(plan["p_out"]),
                jnp.asarray(stale[w]),
                *[jnp.asarray(p) for p in params],
                jnp.asarray(plan["y"]),
                jnp.asarray(plan["mask"]),
            )
            loss, _ncorr, logits, rep = out[0], out[1], out[2], out[3]
            grads = [np.asarray(g) for g in out[4:]]
            loss_epoch += float(loss)
            # held-out accuracy from the same logits
            logits = np.asarray(logits)
            preds = logits.argmax(1)
            vm = plan["val_mask"]
            val_correct += float(((preds == plan["y"]) * vm).sum())
            val_total += float(vm.sum())
            grads_acc = (
                grads
                if grads_acc is None
                else [a + g for a, g in zip(grads_acc, grads)]
            )
            if exchange and r % sync_interval == 0:
                rep = np.asarray(rep)
                for i, node in enumerate(plan["own"]):
                    kvs[node] = rep[i]
        params = _adam(params, [g / 2 for g in grads_acc], m, v, r + 1)
        losses.append(loss_epoch / 2)
    return losses, val_correct / max(val_total, 1.0)


def test_distributed_training_converges():
    losses, _ = _train(sync_interval=2)
    assert losses[-1] < 0.5 * losses[0], f"{losses[0]} -> {losses[-1]}"


def test_stale_exchange_feeds_gradients():
    """Eq. 6's premise, wired end-to-end: once the first representations
    are exchanged, training trajectories with and without exchange must
    diverge (the stale term reaches the gradients).  The *quality* claim
    (exchange beats edge-dropping on real graphs) is asserted at the
    Rust level where the scale supports it (integration_training.rs,
    exp::table1)."""
    with_ex, _ = _train(sync_interval=1, exchange=True, epochs=6)
    without, _ = _train(sync_interval=1, exchange=False, epochs=6)
    # as soon as pushes land (worker 1 pulls worker 0's epoch-0 reps
    # within the same round), the trajectories must differ
    assert abs(with_ex[-1] - without[-1]) > 1e-6, f"{with_ex} vs {without}"
    # and both still converge
    assert with_ex[-1] < with_ex[0] and without[-1] < without[0]


def test_fresher_sync_no_worse():
    tight = _train(sync_interval=1)[0]
    loose = _train(sync_interval=20)[0]
    assert tight[-1] <= loose[-1] + 0.05, f"N=1 {tight[-1]} vs N=20 {loose[-1]}"


def test_losses_finite_throughout():
    for n in (1, 5):
        losses, acc = _train(sync_interval=n, epochs=8)
        assert all(np.isfinite(l) for l in losses)
        assert 0.0 <= acc <= 1.0
