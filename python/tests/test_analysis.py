"""Tests for the L2 HLO analysis tooling."""

import pytest

from compile.analysis import analytic_flops, analyze, gemm_estimates, op_histogram
from compile.configs import ArtifactConfig

TINY = ArtifactConfig(
    name="tiny_an", model="gcn", layers=2, s_pad=8, b_pad=8, d_in=4, d_h=4, n_class=3
)


def test_op_histogram_parses_hlo():
    text = """
  %x = f32[4,4]{1,0} parameter(0)
  %y = f32[4,4]{1,0} parameter(1)
  %d = f32[4,4]{1,0} dot(%x, %y), lhs_contracting_dims={1}
  ROOT %a = f32[4,4]{1,0} add(%d, %x)
"""
    ops = op_histogram(text)
    assert ops["parameter"] == 2
    assert ops["dot"] == 1
    assert ops["add"] == 1


def test_analytic_flops_train_is_3x_eval():
    assert analytic_flops(TINY, "train") == 3 * analytic_flops(TINY, "eval")
    assert analytic_flops(TINY, "eval") > 0


def test_gemm_estimates_structure():
    gs = gemm_estimates(TINY)
    names = {g["gemm"] for g in gs}
    assert names == {"transform", "aggregate", "classify"}
    for g in gs:
        assert 0 < g["mxu_utilization"] <= 1
        assert g["vmem_bytes"] > 0
        m, n, k = g["m"], g["n"], g["k"]
        bm, bn, bk = g["blocks"]
        assert m % bm == 0 and n % bn == 0 and k % bk == 0


def test_analyze_real_lowering():
    r = analyze(TINY, "train")
    assert r["total_ops"] > 10
    # the interpret-mode Pallas GEMMs appear as while loops over the grid
    assert r["while_loops"] >= 1
    assert r["dots"] >= 1
    assert r["input_bytes"] > 0
    assert r["analytic_flops"] == analytic_flops(TINY, "train")
