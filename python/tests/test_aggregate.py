"""L1 kernel tests: Pallas blocked GEMM vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-power-of-two and prime-ish
dims), activations, bias on/off, and verifies the custom-vjp backward
pass against jax's autodiff of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.aggregate import (
    ACTIVATIONS,
    aggregate_layer,
    matmul_bias_act,
    mxu_utilization,
    pick_block,
    pmatmul,
    vmem_footprint_bytes,
)

DIMS = st.sampled_from([1, 2, 3, 7, 16, 24, 40, 47, 64, 100, 129])


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------


@given(dim=st.integers(1, 4096), target=st.integers(1, 256))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides_and_bounded(dim, target):
    b = pick_block(dim, target)
    assert dim % b == 0
    assert b <= max(target, 1) or b == dim  # dim <= target returns dim itself
    if dim <= target:
        assert b == dim


def test_pick_block_prefers_large_divisors():
    assert pick_block(256, 128) == 128
    assert pick_block(40, 128) == 40
    assert pick_block(300, 128) == 100
    assert pick_block(129, 128) == 43


# ---------------------------------------------------------------------------
# forward GEMM vs oracle
# ---------------------------------------------------------------------------


@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        pmatmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("act", sorted(ACTIVATIONS))
@pytest.mark.parametrize("with_bias", [False, True])
def test_fused_epilogue_matches_ref(act, with_bias):
    rng = np.random.default_rng(7)
    x, y = _rand(rng, 64, 48), _rand(rng, 48, 40)
    b = _rand(rng, 40) if with_bias else None
    got = matmul_bias_act(x, y, bias=b, act=act)
    want = ref.matmul_ref(x, y, bias=b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_explicit_blocking_matches_default():
    rng = np.random.default_rng(3)
    x, y = _rand(rng, 128, 96), _rand(rng, 96, 64)
    from compile.kernels.aggregate import _pallas_matmul

    base = ref.matmul_ref(x, y)
    for bm, bn, bk in [(32, 32, 32), (128, 64, 96), (64, 16, 48)]:
        got = _pallas_matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, base, rtol=1e-4, atol=1e-4)


def test_bad_shapes_raise():
    x = jnp.zeros((4, 5))
    y = jnp.zeros((6, 3))
    with pytest.raises(ValueError):
        pmatmul(x, y)
    with pytest.raises(ValueError):
        matmul_bias_act(jnp.zeros((4, 6)), y, act="nope")


# ---------------------------------------------------------------------------
# backward pass (custom vjp)
# ---------------------------------------------------------------------------


@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_pmatmul_grads_match_autodiff(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, k), _rand(rng, k, n)

    def f_pallas(x, y):
        return jnp.sum(jnp.tanh(pmatmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.tanh(x @ y))

    gp = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gp[0], gr[0], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gp[1], gr[1], rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# the DIGEST aggregation layer (Eq. 5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("act", ["none", "relu", "elu"])
def test_aggregate_layer_matches_ref(fused, act):
    rng = np.random.default_rng(11)
    s, b, d, dp = 32, 48, 24, 16
    p_in, p_out = _rand(rng, s, s), _rand(rng, s, b)
    h_in, h_st = _rand(rng, s, d), _rand(rng, b, d)
    w, bias = _rand(rng, d, dp), _rand(rng, dp)
    got = aggregate_layer(
        p_in, p_out, h_in, h_st, w, bias=bias, act=act, fused_epilogue=fused
    )
    want = ref.aggregate_layer_ref(p_in, p_out, h_in, h_st, w, bias=bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_aggregate_layer_zero_stale_is_partition_based():
    """With H̃=0 and P_out=0 the layer reduces to the edge-dropping
    (partition-based) computation — the information-loss baseline."""
    rng = np.random.default_rng(5)
    s, b, d, dp = 16, 16, 8, 8
    p_in = _rand(rng, s, s)
    h_in = _rand(rng, s, d)
    w = _rand(rng, d, dp)
    zeros_po, zeros_h = jnp.zeros((s, b)), jnp.zeros((b, d))
    got = aggregate_layer(p_in, zeros_po, h_in, zeros_h, w, act="none")
    np.testing.assert_allclose(got, p_in @ h_in @ w, rtol=1e-4, atol=1e-4)


def test_aggregate_layer_grad_flows_through_stale_term():
    """Thm 1's premise: the gradient depends on H̃_out (Eq. 6)."""
    rng = np.random.default_rng(9)
    s, b, d, dp = 16, 16, 8, 8
    p_in, p_out = _rand(rng, s, s), _rand(rng, s, b)
    h_in, h_st = _rand(rng, s, d), _rand(rng, b, d)
    w = _rand(rng, d, dp)

    def loss(w, h_st):
        return jnp.sum(aggregate_layer(p_in, p_out, h_in, h_st, w, act="relu") ** 2)

    g_with = jax.grad(loss)(w, h_st)
    g_zero = jax.grad(loss)(w, jnp.zeros_like(h_st))
    assert not np.allclose(np.asarray(g_with), np.asarray(g_zero))


# ---------------------------------------------------------------------------
# TPU perf model sanity
# ---------------------------------------------------------------------------


def test_vmem_footprint_within_budget_for_all_configs():
    from compile.configs import CONFIGS

    budget = 16 * 2**20  # 16 MiB per-core VMEM
    for cfg in CONFIGS:
        sb = cfg.s_pad + cfg.b_pad
        # transform GEMM (S+B, d_in) @ (d_in, d_h); aggregate (S, S+B) @ (S+B, d_h)
        assert vmem_footprint_bytes(sb, cfg.d_h, cfg.d_in) < budget, cfg.name
        assert vmem_footprint_bytes(cfg.s_pad, cfg.d_h, sb) < budget, cfg.name


def test_mxu_utilization_model():
    # aligned shapes: full utilization
    assert mxu_utilization(256, 256, 256) == pytest.approx(1.0)
    # a 40-wide N dim wastes most of a 128-lane pass
    assert mxu_utilization(256, 40, 256) == pytest.approx(40 / 128)
    # utilization in (0, 1]
    for m, n, k in [(100, 47, 300), (512, 64, 129)]:
        u = mxu_utilization(m, n, k)
        assert 0 < u <= 1


# ---------------------------------------------------------------------------
# backend dispatch (§Perf)
# ---------------------------------------------------------------------------


def test_xla_backend_matches_pallas():
    """The fast-CPU "xla" backend must be numerically identical."""
    from compile.kernels import aggregate as agg

    rng = np.random.default_rng(21)
    x, y, b = _rand(rng, 32, 24), _rand(rng, 24, 16), _rand(rng, 16)
    base_mm = np.asarray(pmatmul(x, y))
    base_fused = np.asarray(matmul_bias_act(x, y, b, "relu"))
    old = agg.BACKEND
    try:
        agg.set_backend("xla")
        np.testing.assert_allclose(agg.pmatmul(x, y), base_mm, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            agg.matmul_bias_act(x, y, b, "relu"), base_fused, rtol=1e-5, atol=1e-5
        )
    finally:
        agg.BACKEND = old


def test_set_backend_validates():
    from compile.kernels import aggregate as agg

    with pytest.raises(ValueError):
        agg.set_backend("cuda")
